//! Per-model sparsity profiles for the nine paper workloads.
//!
//! Substitution (DESIGN.md): the paper traces full ImageNet-class
//! training runs on a GPU; here the per-tensor sparsity *levels* and
//! their epoch trajectories are encoded explicitly, calibrated to the
//! paper's reported anchors:
//!
//! * Fig. 1 — potential (allMACs/remainingMACs) averages ~3x across
//!   models; DenseNet121 lowest but > 1.5x; SqueezeNet > 2x.
//! * Fig. 13 — average TensorDash speedup 1.95x; DenseNet's W*G op is
//!   negligible (batch-norm absorbs gradient sparsity).
//! * Fig. 14 — dense models follow an inverted-U over epochs;
//!   resnet50_DS90 starts ~1.95x and settles ~1.8x; resnet50_SM90
//!   starts ~1.75x and settles ~1.5x; all stabilise after ~5% of
//!   training.
//! * §4.4 — GCN has virtually no sparsity (~1% gain).
//!
//! Per-layer sparsity additionally rises with depth (deeper layers
//! detect more specific features => more zeros), and the generated
//! bitmaps use the §4.4 *clustered* structure (non-zeros concentrate in
//! a subset of feature maps).

use crate::conv::{ConvShape, TrainOp};
use crate::models::{topology, Topology, BATCH};
use crate::tensor::TensorBitmap;
use crate::trace::synthetic::clustered_bitmap;
use crate::util::rng::Rng;

/// Epoch phases sampled for Fig. 14 (fractions of total training).
pub const PHASES: [f64; 10] = [0.0, 0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.65, 0.8, 1.0];

/// How a model's sparsity evolves over training: the Fig. 14 families
/// are [`crate::sparsity::Curve`] values, so a `Schedule` regime can
/// reuse (or replace) any model's built-in trajectory.
pub use crate::sparsity::Curve;

/// A workload with calibrated sparsity levels.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub topology: Topology,
    /// Base zero-fraction of the input activations (op-1/op-3 operand).
    pub a_sparsity: f64,
    /// Base zero-fraction of the output gradients (op-2/op-3 operand).
    pub g_sparsity: f64,
    pub curve: Curve,
    /// Fraction of feature maps carrying most non-zeros (§4.4).
    pub cluster: f64,
    /// Per-layer depth gradient: sparsity scaled by
    /// `1 + depth_slope * (layer_frac - 0.5)`.
    pub depth_slope: f64,
    /// The batch size the paper traced for this model (64–143); the
    /// simulator scales batch-dependent work from the small generated
    /// batch up to this (DESIGN.md sampling substitution).
    pub paper_batch: usize,
    /// Weight sparsity: ~0 for dense models ("weights exhibit negligible
    /// sparsity during training unless the training method incorporates
    /// pruning", §2); 0.9 for the DS90/SM90 pruned-training variants.
    pub w_sparsity: f64,
}

impl ModelProfile {
    pub fn name(&self) -> &str {
        self.topology.name
    }

    /// The calibrated profile for a paper workload.
    pub fn for_model(name: &str) -> Option<ModelProfile> {
        let topo = topology(name, BATCH)?;
        // (a_sparsity, g_sparsity, curve, cluster, depth_slope, batch)
        let (sa, sg, curve, cluster, slope, batch) = match name {
            "alexnet" => (0.55, 0.70, Curve::DenseU { swing: 0.35 }, 0.35, 0.35, 128),
            "vgg16" => (0.63, 0.78, Curve::DenseU { swing: 0.32 }, 0.35, 0.35, 64),
            "squeezenet" => (0.52, 0.68, Curve::DenseU { swing: 0.18 }, 0.40, 0.25, 143),
            "resnet50" => (0.52, 0.66, Curve::DenseU { swing: 0.15 }, 0.40, 0.30, 96),
            "resnet50_DS90" => {
                (0.55, 0.59, Curve::PrunedReclaim { start_boost: 0.10 }, 0.35, 0.15, 96)
            }
            "resnet50_SM90" => {
                (0.40, 0.43, Curve::PrunedReclaim { start_boost: 0.22 }, 0.35, 0.15, 96)
            }
            "densenet121" => (0.48, 0.03, Curve::DenseU { swing: 0.12 }, 0.45, 0.20, 64),
            "img2txt" => (0.60, 0.74, Curve::DenseU { swing: 0.20 }, 0.40, 0.20, 64),
            "snli" => (0.50, 0.62, Curve::DenseU { swing: 0.18 }, 0.45, 0.10, 143),
            "gcn" => (0.02, 0.015, Curve::Flat, 0.90, 0.0, 96),
            // BERT-class encoder: GELU FFNs and attention keep more
            // values live than post-ReLU CNN maps, gradients sparser
            // than activations, shallow depth gradient across blocks.
            "bert" => (0.45, 0.60, Curve::DenseU { swing: 0.25 }, 0.40, 0.15, 64),
            _ => return None,
        };
        let w_sparsity = match name {
            "resnet50_DS90" | "resnet50_SM90" => 0.9,
            _ => 0.0,
        };
        Some(ModelProfile {
            topology: topo,
            a_sparsity: sa,
            g_sparsity: sg,
            curve,
            cluster,
            depth_slope: slope,
            paper_batch: batch,
            w_sparsity,
        })
    }

    pub fn all() -> Vec<ModelProfile> {
        crate::models::FIG13_MODELS
            .iter()
            .map(|m| ModelProfile::for_model(m).unwrap())
            .collect()
    }

    /// Work multiplier from the simulated batch up to the paper's batch.
    pub fn batch_mult(&self) -> u64 {
        (self.paper_batch / BATCH).max(1) as u64
    }

    fn depth_factor(&self, layer_idx: usize) -> f64 {
        let n = self.topology.layers.len().max(2);
        let frac = layer_idx as f64 / (n - 1) as f64;
        1.0 + self.depth_slope * (frac - 0.5)
    }

    /// Sparsity of the A tensor of layer `i` under an explicit curve
    /// multiplier (the `Schedule` regime's evaluation point).
    pub fn a_sparsity_with_factor(&self, i: usize, factor: f64) -> f64 {
        (self.a_sparsity * self.depth_factor(i) * factor).clamp(0.0, 0.98)
    }

    /// Sparsity of the G tensor of layer `i` under an explicit curve
    /// multiplier.
    pub fn g_sparsity_with_factor(&self, i: usize, factor: f64) -> f64 {
        (self.g_sparsity * self.depth_factor(i) * factor).clamp(0.0, 0.98)
    }

    /// Sparsity of the A tensor of layer `i` at epoch fraction `e`.
    pub fn a_sparsity_at(&self, i: usize, e: f64) -> f64 {
        self.a_sparsity_with_factor(i, self.curve.factor(e))
    }

    /// Sparsity of the G tensor of layer `i` at epoch fraction `e`.
    pub fn g_sparsity_at(&self, i: usize, e: f64) -> f64 {
        self.g_sparsity_with_factor(i, self.curve.factor(e))
    }

    /// Generate the (A, G) bitmaps of layer `i` at epoch fraction `e`.
    /// Deterministic in `(model, layer, epoch, seed)`.
    pub fn layer_bitmaps(&self, i: usize, e: f64, seed: u64) -> (TensorBitmap, TensorBitmap) {
        self.layer_bitmaps_with_factor(i, e, seed, self.curve.factor(e))
    }

    /// Same generator with the curve multiplier supplied by the caller
    /// (the `Schedule` regime). The RNG stream depends only on
    /// `(model, layer, epoch, seed)` — never on the factor — so
    /// scheduling a model onto its own curve is bit-identical to
    /// [`Self::layer_bitmaps`].
    pub fn layer_bitmaps_with_factor(
        &self,
        i: usize,
        e: f64,
        seed: u64,
        factor: f64,
    ) -> (TensorBitmap, TensorBitmap) {
        let s: &ConvShape = &self.topology.layers[i].shape;
        let mut rng = Rng::new(
            seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ ((e * 1000.0) as u64).wrapping_mul(0xD1B54A32D192ED03)
                ^ self.name().bytes().fold(0u64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64)),
        );
        let a = clustered_bitmap(
            (s.n, s.h, s.w, s.c),
            self.a_sparsity_with_factor(i, factor),
            self.cluster,
            &mut rng,
        );
        let g = clustered_bitmap(
            (s.n, s.out_h(), s.out_w(), s.f),
            self.g_sparsity_with_factor(i, factor),
            self.cluster,
            &mut rng,
        );
        (a, g)
    }

    /// Generate the weight bitmap of layer `i` as an `(f, kh, kw, c)`
    /// tensor (unstructured pruning for the DS90/SM90 variants; the
    /// pruned fraction is stable after the first epochs, Fig. 14).
    pub fn layer_weight_bitmap(&self, i: usize, seed: u64) -> TensorBitmap {
        let s: &ConvShape = &self.topology.layers[i].shape;
        let mut rng = Rng::new(seed ^ 0x57EED ^ (i as u64) << 17);
        crate::trace::synthetic::random_bitmap(
            (s.f, s.kh, s.kw, s.c),
            self.w_sparsity,
            &mut rng,
        )
    }

    /// Fig. 1 potential speedup of one op on one layer: total MACs over
    /// remaining MACs after dropping those whose targeted operand is 0.
    pub fn potential(&self, i: usize, op: TrainOp, e: f64) -> f64 {
        let (sa, sg) = (self.a_sparsity_at(i, e), self.g_sparsity_at(i, e));
        let s = match op {
            TrainOp::Fwd => sa,
            TrainOp::Igrad => sg,
            TrainOp::Wgrad => sa.max(sg),
        };
        1.0 / (1.0 - s).max(0.02)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_nine_profiles_exist() {
        let all = ModelProfile::all();
        assert_eq!(all.len(), 9);
        assert!(ModelProfile::for_model("unknown").is_none());
    }

    #[test]
    fn fig1_anchor_potentials() {
        // Average potential across models ~3x; DenseNet lowest but
        // >1.5x; SqueezeNet > 2x.
        let mut means = Vec::new();
        for p in ModelProfile::all() {
            let n = p.topology.layers.len();
            let mut acc = 0.0;
            for i in 0..n {
                for op in TrainOp::ALL {
                    acc += p.potential(i, op, 0.4);
                }
            }
            means.push((p.name().to_string(), acc / (3 * n) as f64));
        }
        let overall: f64 = means
            .iter()
            .filter(|(n, _)| n != "gcn")
            .map(|(_, m)| m)
            .sum::<f64>()
            / 8.0;
        assert!((2.2..4.0).contains(&overall), "avg potential {overall}");
        let get = |n: &str| means.iter().find(|(m, _)| m == n).unwrap().1;
        assert!(get("densenet121") > 1.5, "densenet {}", get("densenet121"));
        assert!(get("squeezenet") > 2.0);
        assert!(get("densenet121") < get("squeezenet"));
    }

    #[test]
    fn epoch_curves_match_fig14_shape() {
        let dense = Curve::DenseU { swing: 0.3 };
        assert!(dense.factor(0.0) < dense.factor(0.2));
        assert!(dense.factor(0.3) > dense.factor(0.9)); // late dip
        assert!((dense.factor(0.2) - dense.factor(0.4)).abs() < 1e-9); // plateau
        let pruned = Curve::PrunedReclaim { start_boost: 0.2 };
        assert!(pruned.factor(0.0) > pruned.factor(0.05));
        assert!((pruned.factor(0.05) - 1.0).abs() < 1e-9);
        assert!((pruned.factor(0.8) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn layer_bitmaps_match_profile_density() {
        let p = ModelProfile::for_model("resnet50").unwrap();
        let (a, g) = p.layer_bitmaps(10, 0.4, 42);
        assert!((a.sparsity() - p.a_sparsity_at(10, 0.4)).abs() < 0.06);
        assert!((g.sparsity() - p.g_sparsity_at(10, 0.4)).abs() < 0.06);
    }

    #[test]
    fn bitmaps_deterministic() {
        let p = ModelProfile::for_model("alexnet").unwrap();
        let (a1, _) = p.layer_bitmaps(2, 0.4, 7);
        let (a2, _) = p.layer_bitmaps(2, 0.4, 7);
        assert_eq!(a1, a2);
        let (a3, _) = p.layer_bitmaps(2, 0.4, 8);
        assert_ne!(a1, a3);
    }

    #[test]
    fn own_curve_factor_is_bit_identical() {
        // The Schedule regime's contract: supplying a model's own curve
        // factor reproduces the default generator exactly.
        for name in ["resnet50", "gcn", "bert"] {
            let p = ModelProfile::for_model(name).unwrap();
            let f = p.curve.factor(0.3);
            let (a1, g1) = p.layer_bitmaps(1, 0.3, 42);
            let (a2, g2) = p.layer_bitmaps_with_factor(1, 0.3, 42, f);
            assert_eq!(a1, a2, "{name} A diverged");
            assert_eq!(g1, g2, "{name} G diverged");
        }
    }

    #[test]
    fn bert_profile_exists_outside_fig13() {
        let p = ModelProfile::for_model("bert").unwrap();
        assert_eq!(p.name(), "bert");
        assert!(p.a_sparsity_at(0, 0.4) > 0.3);
        assert!(p.g_sparsity_at(0, 0.4) > p.a_sparsity_at(0, 0.4));
        // The fig-13 set stays the paper's nine models.
        assert!(!crate::models::FIG13_MODELS.contains(&"bert"));
    }

    #[test]
    fn densenet_gradients_absorbed_by_bn() {
        let p = ModelProfile::for_model("densenet121").unwrap();
        assert!(p.g_sparsity_at(50, 0.4) < 0.05);
        // => W*G potential ~1 (negligible, Fig. 13) unless A is chosen.
        let pot = p.potential(50, TrainOp::Igrad, 0.4);
        assert!(pot < 1.1);
    }

    #[test]
    fn gcn_is_the_no_sparsity_control() {
        let p = ModelProfile::for_model("gcn").unwrap();
        for i in 0..p.topology.layers.len() {
            assert!(p.a_sparsity_at(i, 0.5) < 0.05);
            assert!(p.g_sparsity_at(i, 0.5) < 0.05);
        }
    }
}
