//! Real-trace capture: bitmap words from the AOT train step -> simulator.
//!
//! The train-step artifact (python/compile/model.py) returns, besides the
//! updated parameters and metrics, one packed int32 bitmap word per
//! 16-channel group for every layer's input activations (`A_l`) and
//! output-activation gradients (`G_l`) — computed on-device by the
//! Pallas `zero_bitmap16` kernel. This module reassembles them into
//! [`TensorBitmap`]s keyed to the model's layer geometry.

use crate::conv::ConvShape;
use crate::tensor::TensorBitmap;

/// One training step's sparsity observation for a whole model.
#[derive(Debug, Clone)]
pub struct StepTrace {
    /// Per conv layer: (A bitmap, G bitmap).
    pub layers: Vec<(TensorBitmap, TensorBitmap)>,
    pub loss: f32,
    pub accuracy: f32,
}

impl StepTrace {
    /// Assemble from raw artifact outputs.
    ///
    /// `shapes[i]` is layer i's geometry; `a_words[i]` / `g_words[i]` the
    /// packed words for its input-activation / output-gradient tensors.
    pub fn from_words(
        shapes: &[ConvShape],
        a_words: &[Vec<i32>],
        g_words: &[Vec<i32>],
        loss: f32,
        accuracy: f32,
    ) -> anyhow::Result<StepTrace> {
        anyhow::ensure!(
            shapes.len() == a_words.len() && shapes.len() == g_words.len(),
            "layer count mismatch: {} shapes, {} A, {} G",
            shapes.len(),
            a_words.len(),
            g_words.len()
        );
        let mut layers = Vec::with_capacity(shapes.len());
        for (i, s) in shapes.iter().enumerate() {
            let a_dims = (s.n, s.h, s.w, s.c);
            let g_dims = (s.n, s.out_h(), s.out_w(), s.f);
            anyhow::ensure!(
                a_words[i].len() * 16 == s.n * s.h * s.w * s.c,
                "layer {i}: A words {} != {} values / 16",
                a_words[i].len(),
                s.n * s.h * s.w * s.c,
            );
            layers.push((
                TensorBitmap::from_words_i32(a_dims, &a_words[i]),
                TensorBitmap::from_words_i32(g_dims, &g_words[i]),
            ));
        }
        Ok(StepTrace { layers, loss, accuracy })
    }

    /// Mean sparsity across all captured tensors (progress logging).
    pub fn mean_sparsity(&self) -> (f64, f64) {
        let n = self.layers.len().max(1) as f64;
        let a = self.layers.iter().map(|(a, _)| a.sparsity()).sum::<f64>() / n;
        let g = self.layers.iter().map(|(_, g)| g.sparsity()).sum::<f64>() / n;
        (a, g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shapes() -> Vec<ConvShape> {
        vec![
            ConvShape::conv(2, 4, 4, 16, 32, 3, 1, 1),
            ConvShape::conv(2, 4, 4, 32, 32, 3, 2, 1),
        ]
    }

    #[test]
    fn reassembles_bitmaps() {
        let s = shapes();
        let a0 = vec![0x0F0Fu16 as i32; 2 * 4 * 4 * 1];
        let g0 = vec![0xFFFF_u16 as i32; 2 * 4 * 4 * 2];
        let a1 = vec![0i32; 2 * 4 * 4 * 2];
        let g1 = vec![1i32; 2 * 2 * 2 * 2];
        let t = StepTrace::from_words(&s, &[a0, a1], &[g0, g1], 2.5, 0.1).unwrap();
        assert_eq!(t.layers.len(), 2);
        assert!((t.layers[0].0.sparsity() - 0.5).abs() < 1e-9);
        assert_eq!(t.layers[0].1.density(), 1.0);
        assert_eq!(t.layers[1].0.nonzeros(), 0);
        assert!(t.layers[1].1.bit(0, 0, 0, 0));
        assert!(!t.layers[1].1.bit(0, 0, 0, 1));
        let (ma, mg) = t.mean_sparsity();
        assert!(ma > 0.7 && mg < 0.6);
    }

    #[test]
    fn rejects_mismatched_counts() {
        let s = shapes();
        assert!(StepTrace::from_words(&s, &[vec![0; 4]], &[vec![], vec![]], 0.0, 0.0).is_err());
        // wrong word count for layer 0
        assert!(
            StepTrace::from_words(
                &s,
                &[vec![0; 3], vec![0; 64]],
                &[vec![0; 64], vec![0; 16]],
                0.0,
                0.0,
            )
            .is_err()
        );
    }
}
