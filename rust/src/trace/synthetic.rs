//! Synthetic sparse tensors.
//!
//! [`random_bitmap`] draws each element i.i.d. — the paper's Fig. 20
//! setup ("synthetically generated sparse tensors with sparsity levels
//! from 10% up to 90%", uniformly random values).
//!
//! [`clustered_bitmap`] models the structure the paper identifies in
//! §4.4: "non-zero activations and gradients tend to cluster in certain
//! 2D feature maps whereas the other 2D maps become more sparse" —
//! per (sample, channel) feature map, a density multiplier splits maps
//! into mostly-dense and mostly-sparse populations while preserving the
//! target average sparsity. This is what creates the row-imbalance that
//! Fig. 17 measures.

use crate::tensor::TensorBitmap;
use crate::util::rng::Rng;

/// i.i.d. Bernoulli bitmap with the given `sparsity` (fraction of zeros).
pub fn random_bitmap(
    dims: (usize, usize, usize, usize),
    sparsity: f64,
    rng: &mut Rng,
) -> TensorBitmap {
    let (n, h, w, c) = dims;
    assert_eq!(c % 16, 0);
    let density = 1.0 - sparsity.clamp(0.0, 1.0);
    let words: Vec<u16> = (0..n * h * w * c / 16).map(|_| rng.mask16(density)).collect();
    TensorBitmap::from_raw(dims, words)
}

/// Cluster strength used for model profiles: fraction of feature maps
/// that hold most of the non-zeros.
pub const DEFAULT_CLUSTER: f64 = 0.35;

/// Clustered bitmap: a fraction `cluster` of the (sample, channel) maps
/// are "feature-present" (dense-ish); the rest are mostly zero. Average
/// density matches `1 - sparsity`.
pub fn clustered_bitmap(
    dims: (usize, usize, usize, usize),
    sparsity: f64,
    cluster: f64,
    rng: &mut Rng,
) -> TensorBitmap {
    let (n, h, w, c) = dims;
    assert_eq!(c % 16, 0);
    let density = (1.0 - sparsity).clamp(0.0, 1.0);
    let cluster = cluster.clamp(0.05, 1.0);
    // Dense maps carry `hi`, sparse maps `lo`, with
    // cluster*hi + (1-cluster)*lo = density and lo = 0.45 * hi (feature-
    // present maps roughly twice as dense as feature-absent maps; real
    // post-ReLU maps keep substantial zeros even when "present").
    const LO_RATIO: f64 = 0.45;
    let hi = (density / (cluster + (1.0 - cluster) * LO_RATIO)).min(1.0);
    let lo = ((density - cluster * hi) / (1.0 - cluster)).max(0.0);
    // Per-(n, c) map density: exactly round(cluster * maps) maps are
    // dense (stratified draw — keeps the realised average density tight
    // even for layers with few feature maps).
    let maps = n * c;
    let k = ((cluster * maps as f64).round() as usize).clamp(1, maps);
    let mut map_density = vec![lo; maps];
    for i in rng.sample_indices(maps, k) {
        map_density[i] = hi;
    }
    // Pre-quantise per-map densities to the batched 8-bit thresholds.
    let thresholds: Vec<[u16; 16]> = (0..n * cb_count(c))
        .map(|mi| {
            let ni = mi / cb_count(c);
            let b = mi % cb_count(c);
            let mut t = [0u16; 16];
            for (l, tl) in t.iter_mut().enumerate() {
                let d = map_density[ni * c + b * 16 + l];
                *tl = if d >= 1.0 {
                    256
                } else if d <= 0.0 {
                    0
                } else {
                    (d * 256.0).round().clamp(1.0, 255.0) as u16
                };
            }
            t
        })
        .collect();
    let cb = cb_count(c);
    let mut words = vec![0u16; n * h * w * cb];
    let mut i = 0;
    for ni in 0..n {
        for _y in 0..h {
            for _x in 0..w {
                for b in 0..cb {
                    words[i] = rng.mask16_thresholds(&thresholds[ni * cb + b]);
                    i += 1;
                }
            }
        }
    }
    TensorBitmap::from_raw(dims, words)
}

#[inline]
fn cb_count(c: usize) -> usize {
    c / 16
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_density_matches_target() {
        let mut rng = Rng::new(1);
        for sp in [0.1, 0.5, 0.9] {
            let bm = random_bitmap((4, 16, 16, 64), sp, &mut rng);
            assert!(
                (bm.sparsity() - sp).abs() < 0.02,
                "target {sp}, got {}",
                bm.sparsity()
            );
        }
    }

    #[test]
    fn clustered_density_matches_target() {
        let mut rng = Rng::new(2);
        for sp in [0.3, 0.6, 0.85] {
            let bm = clustered_bitmap((4, 14, 14, 128), sp, DEFAULT_CLUSTER, &mut rng);
            assert!(
                (bm.sparsity() - sp).abs() < 0.05,
                "target {sp}, got {}",
                bm.sparsity()
            );
        }
    }

    #[test]
    fn clustered_has_per_map_variance() {
        // Variance of per-map density must far exceed the i.i.d. case.
        let mut rng = Rng::new(3);
        let dims = (2, 16, 16, 64);
        let spread = |bm: &TensorBitmap| {
            let mut per_map = Vec::new();
            for n in 0..dims.0 {
                for c in 0..dims.3 {
                    let mut nz = 0u64;
                    for y in 0..dims.1 {
                        for x in 0..dims.2 {
                            nz += bm.bit(n, y, x, c) as u64;
                        }
                    }
                    per_map.push(nz as f64 / (dims.1 * dims.2) as f64);
                }
            }
            let m = per_map.iter().sum::<f64>() / per_map.len() as f64;
            per_map.iter().map(|d| (d - m) * (d - m)).sum::<f64>() / per_map.len() as f64
        };
        let cl = clustered_bitmap(dims, 0.6, DEFAULT_CLUSTER, &mut rng);
        let rd = random_bitmap(dims, 0.6, &mut rng);
        assert!(
            spread(&cl) > 10.0 * spread(&rd),
            "clustered {} vs random {}",
            spread(&cl),
            spread(&rd)
        );
    }

    #[test]
    fn extremes() {
        let mut rng = Rng::new(4);
        assert_eq!(random_bitmap((1, 4, 4, 16), 1.0, &mut rng).nonzeros(), 0);
        assert_eq!(
            random_bitmap((1, 4, 4, 16), 0.0, &mut rng).density(),
            1.0
        );
    }
}
