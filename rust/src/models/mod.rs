//! The evaluation model zoo (paper §4 "DNN models").
//!
//! Layer tables for the nine workloads the paper traces: AlexNet,
//! VGG16, SqueezeNet, ResNet-50 (dense and the two pruned-training
//! variants DS90/SM90), DenseNet121, img2txt (Show-and-Tell), SNLI and
//! GCN (the gated-convolution language model used as the no-sparsity
//! control).
//!
//! Substitutions (DESIGN.md): channel counts are rounded up to multiples
//! of 16 (the PE lane width — real deployments pad exactly the same
//! way); the recurrent models are expressed as the FC layers their
//! time-steps execute; the simulated batch is small (the paper used
//! 64–143 samples/batch; sparsity statistics, not batch size, drive the
//! simulator).

use crate::conv::ConvShape;

/// One named layer of a workload.
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub shape: ConvShape,
}

/// A workload topology.
#[derive(Debug, Clone)]
pub struct Topology {
    pub name: &'static str,
    pub layers: Vec<Layer>,
}

fn r16(c: usize) -> usize {
    c.div_ceil(16) * 16
}

fn conv(
    name: impl Into<String>,
    n: usize,
    hw: usize,
    c: usize,
    f: usize,
    k: usize,
    s: usize,
    p: usize,
) -> Layer {
    Layer { name: name.into(), shape: ConvShape::conv(n, hw, hw, r16(c), r16(f), k, s, p) }
}

fn fc(name: impl Into<String>, n: usize, c: usize, f: usize) -> Layer {
    Layer { name: name.into(), shape: ConvShape::fc(n, r16(c), r16(f)) }
}

/// Simulated batch size (see module docs).
pub const BATCH: usize = 4;

pub fn alexnet(n: usize) -> Topology {
    Topology {
        name: "alexnet",
        layers: vec![
            conv("conv1", n, 227, 3, 96, 11, 4, 0),
            conv("conv2", n, 27, 96, 256, 5, 1, 2),
            conv("conv3", n, 13, 256, 384, 3, 1, 1),
            conv("conv4", n, 13, 384, 384, 3, 1, 1),
            conv("conv5", n, 13, 384, 256, 3, 1, 1),
            fc("fc6", n, 9216, 4096),
            fc("fc7", n, 4096, 4096),
            fc("fc8", n, 4096, 1000),
        ],
    }
}

pub fn vgg16(n: usize) -> Topology {
    let mut layers = Vec::new();
    let blocks: [(usize, usize, usize); 5] =
        [(224, 64, 2), (112, 128, 2), (56, 256, 3), (28, 512, 3), (14, 512, 3)];
    let mut cin = 3;
    for (bi, (hw, ch, reps)) in blocks.iter().enumerate() {
        for r in 0..*reps {
            layers.push(conv(format!("conv{}_{}", bi + 1, r + 1), n, *hw, cin, *ch, 3, 1, 1));
            cin = *ch;
        }
    }
    layers.push(fc("fc6", n, 25088, 4096));
    layers.push(fc("fc7", n, 4096, 4096));
    layers.push(fc("fc8", n, 4096, 1000));
    Topology { name: "vgg16", layers }
}

pub fn squeezenet(n: usize) -> Topology {
    let mut layers = vec![conv("conv1", n, 224, 3, 96, 7, 2, 3)];
    // (hw, c_in, squeeze, expand) per fire module (v1.0).
    let fires: [(usize, usize, usize, usize); 8] = [
        (56, 96, 16, 64),
        (56, 128, 16, 64),
        (56, 128, 32, 128),
        (28, 256, 32, 128),
        (28, 256, 48, 192),
        (28, 384, 48, 192),
        (28, 384, 64, 256),
        (14, 512, 64, 256),
    ];
    for (i, (hw, cin, sq, ex)) in fires.iter().enumerate() {
        let f = i + 2;
        layers.push(conv(format!("fire{f}_squeeze"), n, *hw, *cin, *sq, 1, 1, 0));
        layers.push(conv(format!("fire{f}_expand1"), n, *hw, *sq, *ex, 1, 1, 0));
        layers.push(conv(format!("fire{f}_expand3"), n, *hw, *sq, *ex, 3, 1, 1));
    }
    layers.push(conv("conv10", n, 14, 512, 1000, 1, 1, 0));
    Topology { name: "squeezenet", layers }
}

pub fn resnet50(n: usize) -> Topology {
    let mut layers = vec![conv("conv1", n, 224, 3, 64, 7, 2, 3)];
    // (stage hw, bottleneck width, out channels, blocks)
    let stages: [(usize, usize, usize, usize); 4] =
        [(56, 64, 256, 3), (28, 128, 512, 4), (14, 256, 1024, 6), (7, 512, 2048, 3)];
    let mut cin = 64;
    for (si, (hw, width, cout, blocks)) in stages.iter().enumerate() {
        for b in 0..*blocks {
            let stride = if b == 0 && si > 0 { 2 } else { 1 };
            let in_hw = if stride == 2 { hw * 2 } else { *hw };
            let tag = format!("s{}b{}", si + 2, b + 1);
            layers.push(conv(format!("{tag}_1x1a"), n, in_hw, cin, *width, 1, stride, 0));
            layers.push(conv(format!("{tag}_3x3"), n, *hw, *width, *width, 3, 1, 1));
            layers.push(conv(format!("{tag}_1x1b"), n, *hw, *width, *cout, 1, 1, 0));
            if b == 0 {
                layers.push(conv(format!("{tag}_down"), n, in_hw, cin, *cout, 1, stride, 0));
            }
            cin = *cout;
        }
    }
    layers.push(fc("fc", n, 2048, 1000));
    Topology { name: "resnet50", layers }
}

pub fn densenet121(n: usize) -> Topology {
    let growth = 32;
    let mut layers = vec![conv("conv1", n, 224, 3, 64, 7, 2, 3)];
    let mut ch = 64;
    let mut hw = 56;
    for (bi, nlayers) in [6usize, 12, 24, 16].iter().enumerate() {
        for l in 0..*nlayers {
            layers.push(conv(format!("b{}l{}_1x1", bi + 1, l + 1), n, hw, ch, 4 * growth, 1, 1, 0));
            let name = format!("b{}l{}_3x3", bi + 1, l + 1);
            layers.push(conv(name, n, hw, 4 * growth, growth, 3, 1, 1));
            ch += growth;
        }
        if bi < 3 {
            layers.push(conv(format!("trans{}", bi + 1), n, hw, ch, ch / 2, 1, 1, 0));
            ch /= 2;
            hw /= 2;
        }
    }
    layers.push(fc("fc", n, ch, 1000));
    Topology { name: "densenet121", layers }
}

/// Show-and-Tell (img2txt): Inception-style encoder (representative
/// subset) + LSTM decoder time-steps as FC layers + word projection.
pub fn img2txt(n: usize) -> Topology {
    let mut layers = vec![
        conv("enc_conv1", n, 299, 3, 32, 3, 2, 0),
        conv("enc_conv2", n, 149, 32, 32, 3, 1, 0),
        conv("enc_conv3", n, 147, 32, 64, 3, 1, 1),
        conv("enc_conv4", n, 73, 64, 80, 1, 1, 0),
        conv("enc_conv5", n, 73, 80, 192, 3, 1, 0),
        conv("enc_mix1", n, 35, 192, 256, 3, 1, 1),
        conv("enc_mix2", n, 17, 256, 512, 3, 2, 1),
        conv("enc_mix3", n, 8, 512, 1280, 3, 2, 1),
        fc("embed", n, 2048, 512),
    ];
    // 8 decoder steps; each step computes the 4 LSTM gates as one GEMM
    // (x_t ++ h_{t-1}) x W -> 4*512.
    for t in 0..8 {
        layers.push(fc(format!("lstm_t{t}"), n, 1024, 2048));
    }
    layers.push(fc("word_proj", n, 512, 10000));
    Topology { name: "img2txt", layers }
}

/// SNLI classifier (Bowman et al. baseline): embedding projection, two
/// sentence encoders, and a 3-layer 600-d classifier MLP. Token
/// positions fold into the batch dimension (seq len 20 per premise /
/// hypothesis).
pub fn snli(n: usize) -> Topology {
    let tokens = n * 20;
    Topology {
        name: "snli",
        layers: vec![
            fc("embed_proj", tokens * 2, 304, 304),
            fc("premise_enc", tokens, 304, 304),
            fc("hypothesis_enc", tokens, 304, 304),
            fc("mlp1", n, 608, 608),
            fc("mlp2", n, 608, 608),
            fc("mlp3", n, 608, 608),
            fc("classifier", n, 608, 16),
        ],
    }
}

/// GCN — Dauphin et al. gated convolutional language model (wikitext-2).
/// 1-D causal convolutions over the sequence; gating keeps values mostly
/// non-zero, which is why the paper uses it as the no-sparsity control.
/// The width-4 causal convolutions are expressed as their unfolded GEMM
/// (each output token contracts 4 x 912 inputs) — identical MAC count
/// and stream structure, and no spurious 2-D padding halos.
pub fn gcn(n: usize) -> Topology {
    let seq = 32;
    let mut layers = vec![fc("embed", n * seq, 912, 912)];
    for l in 0..8 {
        // width-4 1-D conv, 912 -> 2x912 (gate pairs), unfolded.
        layers.push(fc(format!("gconv{l}"), n * seq, 4 * 912, 1824));
    }
    layers.push(fc("adaptive_softmax", n * seq, 912, 10000));
    Topology { name: "gcn", layers }
}

/// BERT-class transformer encoder (the scenario tier beyond the paper's
/// 2020 zoo). Two representative encoder blocks at hidden size 768,
/// 12 heads of 64, FFN 3072, sequence length 128; token positions fold
/// into the batch dimension exactly like snli/gcn, and the attention
/// matmuls fold (batch × head) the same way:
///
/// * `q`/`k`/`v`/`proj` — the four 768×768 projections over all tokens;
/// * `attn_score` — Q·Kᵀ per head: each of the `n*12` head-batches
///   contracts 64 channels into 128 key positions, for all 128 queries;
/// * `attn_ctx` — scores·V per head: 128 key positions contract into
///   the 64-wide head output;
/// * `ffn_up`/`ffn_down` — the 768→3072→768 MLP.
pub fn bert(n: usize) -> Topology {
    let (seq, d, heads, head_dim, ffn) = (128, 768, 12, 64, 3072);
    let tokens = n * seq;
    let mut layers = Vec::new();
    for l in 0..2 {
        layers.push(fc(format!("enc{l}_q"), tokens, d, d));
        layers.push(fc(format!("enc{l}_k"), tokens, d, d));
        layers.push(fc(format!("enc{l}_v"), tokens, d, d));
        layers.push(fc(format!("enc{l}_attn_score"), n * heads * seq, head_dim, seq));
        layers.push(fc(format!("enc{l}_attn_ctx"), n * heads * seq, seq, head_dim));
        layers.push(fc(format!("enc{l}_proj"), tokens, d, d));
        layers.push(fc(format!("enc{l}_ffn_up"), tokens, d, ffn));
        layers.push(fc(format!("enc{l}_ffn_down"), tokens, ffn, d));
    }
    Topology { name: "bert", layers }
}

/// Every paper workload by name (the ResNet pruned variants share the
/// resnet50 topology; their difference lives in the sparsity profile).
pub fn topology(name: &str, n: usize) -> Option<Topology> {
    Some(match name {
        "alexnet" => alexnet(n),
        "vgg16" => vgg16(n),
        "squeezenet" => squeezenet(n),
        "resnet50" | "resnet50_DS90" | "resnet50_SM90" => {
            let mut t = resnet50(n);
            t.name = match name {
                "resnet50_DS90" => "resnet50_DS90",
                "resnet50_SM90" => "resnet50_SM90",
                _ => "resnet50",
            };
            t
        }
        "densenet121" => densenet121(n),
        "img2txt" => img2txt(n),
        "snli" => snli(n),
        "gcn" => gcn(n),
        "bert" => bert(n),
        _ => return None,
    })
}

/// The Fig. 13 model list (order of the paper's figures).
pub const FIG13_MODELS: [&str; 9] = [
    "alexnet",
    "densenet121",
    "img2txt",
    "resnet50_DS90",
    "resnet50_SM90",
    "snli",
    "squeezenet",
    "vgg16",
    "resnet50",
];

/// Every name [`topology`] resolves: the paper's nine plus the
/// transformer tier. The fig-13 drivers stay pinned to the paper set;
/// `info`, `simulate`, `serve` and `explore` accept all of these.
pub const ALL_MODELS: [&str; 10] = [
    "alexnet",
    "densenet121",
    "img2txt",
    "resnet50_DS90",
    "resnet50_SM90",
    "snli",
    "squeezenet",
    "vgg16",
    "resnet50",
    "bert",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_build_and_are_lane_aligned() {
        for name in ALL_MODELS {
            let t = topology(name, BATCH).unwrap();
            assert!(!t.layers.is_empty(), "{name} empty");
            for l in &t.layers {
                assert_eq!(l.shape.c % 16, 0, "{name}/{} c", l.name);
                assert_eq!(l.shape.f % 16, 0, "{name}/{} f", l.name);
                assert!(l.shape.out_h() > 0 && l.shape.out_w() > 0);
            }
        }
        assert!(topology("nope", 4).is_none());
    }

    #[test]
    fn layer_counts_are_representative() {
        assert_eq!(alexnet(4).layers.len(), 8);
        assert_eq!(vgg16(4).layers.len(), 16);
        assert_eq!(squeezenet(4).layers.len(), 26);
        // 16 bottlenecks x 3 + 4 downsamples + conv1 + fc = 54.
        assert_eq!(resnet50(4).layers.len(), 54);
        // 58 dense-block convs x2 + 3 transitions + conv1 + fc = 121.
        assert_eq!(densenet121(4).layers.len(), 121);
    }

    #[test]
    fn resnet_macs_scale_sane() {
        // ResNet-50 forward is ~4.1 GMACs per 224x224 image; with lane
        // padding (3->16 in conv1) we land a bit above.
        let t = resnet50(1);
        let macs: u64 = t.layers.iter().map(|l| l.shape.macs()).sum();
        let g = macs as f64 / 1e9;
        assert!((3.5..7.0).contains(&g), "resnet50 {g} GMACs");
    }

    #[test]
    fn bert_encoder_geometry() {
        let t = bert(BATCH);
        // 2 encoder blocks x (QKV + score + ctx + proj + 2 FFN) = 16.
        assert_eq!(t.layers.len(), 16);
        // Attention matmuls fold (batch x heads x queries) into n.
        let score = t.layers.iter().find(|l| l.name == "enc0_attn_score").unwrap();
        assert_eq!(score.shape.n, BATCH * 12 * 128);
        assert_eq!((score.shape.c, score.shape.f), (64, 128));
        let ffn = t.layers.iter().find(|l| l.name == "enc1_ffn_up").unwrap();
        assert_eq!(ffn.shape.n, BATCH * 128);
        assert_eq!((ffn.shape.c, ffn.shape.f), (768, 3072));
        // The paper's figure set is untouched by the new tier.
        assert!(!FIG13_MODELS.contains(&"bert"));
        assert_eq!(&ALL_MODELS[..9], &FIG13_MODELS[..]);
    }

    #[test]
    fn densenet_channel_growth() {
        let t = densenet121(1);
        // Final FC input is 1024 channels (64 + 32*58 halved 3 times...).
        let fcl = &t.layers.last().unwrap().shape;
        assert_eq!(fcl.c, 1024);
    }
}
