#!/usr/bin/env python3
"""Service e2e load harness.

Phase 1 — concurrent load. Starts ``tensordash serve`` with a bounded
worker pool (``--workers/--queue-depth``) over a sharded unit cache
(``--shards``), fans N concurrent TCP clients at it, each holding one
persistent connection and issuing several overlapping sweep requests,
and asserts:

* every response is ok, ids come back in request order per connection,
  and the ``report`` bodies are byte-identical across all clients (the
  serving layer's determinism contract under concurrency);
* a batch of duplicate sub-requests reports a nonzero ``coalesced``
  count (duplicate units computed once);
* cumulative stats report nonzero hits/inserts and the configured
  shard count;
* a ``shutdown`` op is acknowledged and the server exits cleanly (0).

Phase 2 — backpressure. Restarts the server with ``--workers 1
--queue-depth 1``, occupies the worker with one connection, queues a
second, and asserts a third is shed with an explicit in-protocol
"overloaded" error line; then shuts down cleanly.

Usage: python3 ci/serve_smoke.py [path/to/tensordash]
"""

import json
import socket
import subprocess
import sys
import threading
import time

BIN = sys.argv[1] if len(sys.argv) > 1 else "target/release/tensordash"
HOST = "127.0.0.1"
PORT = 17871

CLIENTS = 6
# Two overlapping sweeps (the two-model sweep's gcn cells are the
# one-model sweep's whole unit set), alternated per client.
SWEEPS = [
    {"op": "sweep", "models": ["alexnet", "gcn"], "samples": 1, "seed": 42},
    {"op": "sweep", "models": ["gcn"], "samples": 1, "seed": 42},
]
REQS_PER_CLIENT = 4


def wait_for_port(proc, port, timeout=60.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if proc.poll() is not None:
            raise SystemExit(f"server exited early with code {proc.returncode}")
        try:
            with socket.create_connection((HOST, port), timeout=1.0):
                return
        except OSError:
            time.sleep(0.2)
    raise SystemExit("server never opened its port")


def roundtrip(payload, port):
    """One-shot connection: send one request object, return the parsed
    response line."""
    with socket.create_connection((HOST, port), timeout=120.0) as sock:
        sock.sendall((json.dumps(payload) + "\n").encode())
        with sock.makefile("r", encoding="utf-8") as f:
            line = f.readline()
    if not line:
        raise SystemExit("connection closed without a response")
    return json.loads(line)


def start_server(port, extra):
    proc = subprocess.Popen(
        [BIN, "serve", "--listen", f"{HOST}:{port}", "--jobs", "2"] + extra,
        stdout=subprocess.PIPE,
        stderr=subprocess.PIPE,
    )
    wait_for_port(proc, port)
    return proc


def stop_server(proc, port):
    bye = roundtrip({"op": "shutdown"}, port)
    assert bye.get("bye") is True, f"no shutdown ack: {bye}"
    code = proc.wait(timeout=60)
    assert code == 0, f"server exited with code {code}"


def run_client(client, port):
    """One persistent connection, REQS_PER_CLIENT sequential requests
    with ids; returns the report bodies in request order."""
    bodies = []
    with socket.create_connection((HOST, port), timeout=120.0) as sock:
        with sock.makefile("r", encoding="utf-8") as f:
            for i in range(REQS_PER_CLIENT):
                req = dict(SWEEPS[i % len(SWEEPS)])
                req["id"] = f"c{client}-r{i}"
                sock.sendall((json.dumps(req) + "\n").encode())
                line = f.readline()
                assert line, f"client {client}: connection closed mid-stream"
                resp = json.loads(line)
                assert resp.get("ok") is True, f"client {client} req {i}: {resp}"
                assert resp.get("id") == req["id"], (
                    f"client {client}: response out of order: {resp.get('id')}"
                )
                bodies.append(json.dumps(resp["report"]))
    return bodies


def phase_concurrent_load():
    proc = start_server(
        PORT, ["--workers", "4", "--queue-depth", "32", "--shards", "16"]
    )
    try:
        results = [None] * CLIENTS
        errors = []

        def fire(i):
            try:
                results[i] = run_client(i, PORT)
            except Exception as e:  # noqa: BLE001 - report, don't hang
                errors.append(f"client {i}: {e}")

        threads = [threading.Thread(target=fire, args=(i,)) for i in range(CLIENTS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
        if errors:
            raise SystemExit("; ".join(errors))

        # Byte-identical bodies across every client (json.dumps
        # preserves the server's key order).
        for i, bodies in enumerate(results):
            assert bodies is not None, f"client {i} returned nothing"
            assert len(bodies) == REQS_PER_CLIENT, f"client {i} lost responses"
            assert bodies == results[0], f"client {i} diverged from client 0"
        print(
            f"ok: {CLIENTS} concurrent clients x {REQS_PER_CLIENT} overlapping "
            "sweeps returned byte-identical bodies in request order"
        )

        # Duplicate sub-requests in one batch must coalesce onto one
        # computation (fresh seed so the units cannot already be
        # cached).
        batch = {
            "op": "batch",
            "requests": [
                {"op": "simulate", "id": "a", "model": "gcn", "samples": 1, "seed": 777},
                {"op": "simulate", "id": "b", "model": "gcn", "samples": 1, "seed": 777},
            ],
        }
        with socket.create_connection((HOST, PORT), timeout=120.0) as sock:
            sock.sendall((json.dumps(batch) + "\n").encode())
            with sock.makefile("r", encoding="utf-8") as f:
                lines = [f.readline(), f.readline()]
        subs = [json.loads(l) for l in lines]
        assert all(r.get("ok") is True for r in subs), f"batch failed: {subs}"
        assert json.dumps(subs[0]["report"]) == json.dumps(subs[1]["report"])
        coalesced = subs[-1].get("cache", {}).get("coalesced", 0)
        assert coalesced > 0, f"batch duplicates did not coalesce: {subs[-1]}"
        print(f"ok: duplicate batch sub-requests coalesced ({coalesced} units)")

        # Cumulative stats: real cache traffic over the configured
        # shard count.
        stats = roundtrip({"op": "stats"}, PORT)
        assert stats.get("ok") is True, f"stats not ok: {stats}"
        total = stats["cache"]
        assert total["inserts"] > 0, f"no units were ever computed: {total}"
        assert total["hits"] > 0, f"no request was ever cache-served: {total}"
        assert total["coalesced"] > 0, f"coalescing telemetry lost: {total}"
        assert stats.get("cache_shards") == 16, f"shard count not reported: {stats}"
        print(
            "ok: cumulative telemetry hits={hits} misses={misses} "
            "inserts={inserts} coalesced={coalesced} over 16 shards".format(**total)
        )

        stop_server(proc, PORT)
        print("ok: clean shutdown under load config (exit 0)")
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=10)


def phase_backpressure():
    port = PORT + 1
    proc = start_server(port, ["--workers", "1", "--queue-depth", "1"])
    try:
        # Occupy the single worker with connection A (a served request
        # proves the worker owns it).
        a = socket.create_connection((HOST, port), timeout=120.0)
        a_file = a.makefile("r", encoding="utf-8")
        a.sendall(b'{"op":"stats","id":"hold"}\n')
        resp = json.loads(a_file.readline())
        assert resp.get("ok") is True, f"hold request failed: {resp}"

        # B parks in the depth-1 queue ...
        b = socket.create_connection((HOST, port), timeout=120.0)
        time.sleep(0.5)

        # ... so C must be shed with an explicit overloaded error line.
        with socket.create_connection((HOST, port), timeout=120.0) as c:
            with c.makefile("r", encoding="utf-8") as f:
                line = f.readline()
        assert line, "shed connection closed without the error line"
        shed = json.loads(line)
        assert shed.get("ok") is False, f"shed response claims ok: {shed}"
        assert "overloaded" in shed.get("error", ""), f"not an overload error: {shed}"
        print(f"ok: queue overflow shed with in-protocol error: {shed['error']}")

        # Shutdown through the in-service connection; B is refused or
        # closed, the process exits 0.
        a.sendall(b'{"op":"shutdown"}\n')
        bye = json.loads(a_file.readline())
        assert bye.get("bye") is True, f"no shutdown ack: {bye}"
        b.close()
        a_file.close()
        a.close()
        code = proc.wait(timeout=60)
        assert code == 0, f"server exited with code {code}"
        print("ok: clean shutdown under backpressure config (exit 0)")
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=10)


def main():
    phase_concurrent_load()
    phase_backpressure()
    print("serve smoke: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
