#!/usr/bin/env python3
"""Service e2e smoke test.

Starts ``tensordash serve`` on a TCP port, fires overlapping duplicate
requests from concurrent connections, and asserts:

* every response is ok and the ``report`` bodies are byte-identical
  across all duplicates (the serving layer's determinism contract);
* a sequential repeat is served from the unit cache with nonzero
  cache-hit telemetry;
* a ``shutdown`` op is acknowledged, the connection closes, and the
  server process exits cleanly (code 0).

Usage: python3 ci/serve_smoke.py [path/to/tensordash]
"""

import json
import socket
import subprocess
import sys
import threading
import time

BIN = sys.argv[1] if len(sys.argv) > 1 else "target/release/tensordash"
HOST = "127.0.0.1"
PORT = 17871
REQUEST = {
    "op": "simulate",
    "id": "dup",
    "model": "alexnet",
    "epoch": 0.4,
    "samples": 1,
    "seed": 42,
}
DUPLICATES = 4


def wait_for_port(proc, timeout=60.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if proc.poll() is not None:
            raise SystemExit(f"server exited early with code {proc.returncode}")
        try:
            with socket.create_connection((HOST, PORT), timeout=1.0):
                return
        except OSError:
            time.sleep(0.2)
    raise SystemExit("server never opened its port")


def roundtrip(payload):
    """Send one request object, return the parsed response line."""
    with socket.create_connection((HOST, PORT), timeout=120.0) as sock:
        sock.sendall((json.dumps(payload) + "\n").encode())
        with sock.makefile("r", encoding="utf-8") as f:
            line = f.readline()
    if not line:
        raise SystemExit("connection closed without a response")
    return json.loads(line)


def main():
    proc = subprocess.Popen(
        [BIN, "serve", "--listen", f"{HOST}:{PORT}", "--jobs", "2"],
        stdout=subprocess.PIPE,
        stderr=subprocess.PIPE,
    )
    try:
        wait_for_port(proc)

        # Overlapping duplicates from concurrent connections.
        results = [None] * DUPLICATES
        errors = []

        def fire(i):
            try:
                results[i] = roundtrip(REQUEST)
            except Exception as e:  # noqa: BLE001 - report, don't hang
                errors.append(f"request {i}: {e}")

        threads = [threading.Thread(target=fire, args=(i,)) for i in range(DUPLICATES)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
        if errors:
            raise SystemExit("; ".join(errors))
        for i, resp in enumerate(results):
            assert resp is not None, f"request {i} got no response"
            assert resp.get("ok") is True, f"request {i} not ok: {resp}"
            assert resp.get("id") == "dup", f"request {i} lost its id: {resp}"

        # Byte-identical bodies: dump preserves the server's key order.
        bodies = [json.dumps(r["report"]) for r in results]
        for i, body in enumerate(bodies[1:], start=1):
            assert body == bodies[0], f"duplicate {i} diverged from duplicate 0"
        print(f"ok: {DUPLICATES} overlapping duplicates returned identical bodies")

        # A sequential repeat must be cache-served: nonzero hit delta.
        repeat = roundtrip(REQUEST)
        assert repeat.get("ok") is True, f"repeat not ok: {repeat}"
        assert json.dumps(repeat["report"]) == bodies[0], "repeat body diverged"
        cache = repeat.get("cache", {})
        assert cache.get("hits", 0) > 0, f"repeat was not cache-served: {cache}"
        assert cache.get("misses", 1) == 0, f"repeat recomputed units: {cache}"
        print(f"ok: sequential repeat fully cache-served ({cache['hits']} hits)")

        # Cumulative stats: every unique unit computed exactly once.
        stats = roundtrip({"op": "stats"})
        assert stats.get("ok") is True, f"stats not ok: {stats}"
        total = stats["cache"]
        assert total["inserts"] > 0, f"no units were ever computed: {total}"
        assert total["hits"] > 0, f"no request was ever cache-served: {total}"
        print(
            "ok: cumulative telemetry hits={hits} misses={misses} "
            "inserts={inserts} coalesced={coalesced}".format(**total)
        )

        # Clean shutdown: ack, then process exit 0.
        bye = roundtrip({"op": "shutdown"})
        assert bye.get("bye") is True, f"no shutdown ack: {bye}"
        code = proc.wait(timeout=60)
        assert code == 0, f"server exited with code {code}"
        print("ok: clean shutdown (exit 0)")
        print("serve smoke: PASS")
        return 0
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=10)


if __name__ == "__main__":
    sys.exit(main())
