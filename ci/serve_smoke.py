#!/usr/bin/env python3
"""Service e2e load harness.

Phase 1 — concurrent load. Starts ``tensordash serve`` with a bounded
worker pool (``--workers/--queue-depth``) over a sharded unit cache
(``--shards``), fans N concurrent TCP clients at it, each holding one
persistent connection and issuing several overlapping sweep requests,
and asserts:

* every response is ok, ids come back in request order per connection,
  and the ``report`` bodies are byte-identical across all clients (the
  serving layer's determinism contract under concurrency);
* a batch of duplicate sub-requests reports a nonzero ``coalesced``
  count (duplicate units computed once);
* cumulative stats report nonzero hits/inserts and the configured
  shard count;
* a ``shutdown`` op is acknowledged and the server exits cleanly (0).

Phase 2 — head-of-line. One connection pipelines a slow cold sweep and
three ``"stream": true`` fast point requests; the fast responses must
arrive *before* the sweep's, each tagged with an ``op`` echo and
byte-identical to a plain v1 roundtrip of the same request, while the
sweep's ordered response arrives last without an echo.

Phase 3 — backpressure. Restarts the server with ``--workers 1
--queue-depth 1``, occupies the worker with a slow sweep, parks one
request in the depth-1 queue, and asserts the next request is shed
with an in-band "overloaded" error *while the connection stays open* —
the same socket then receives its ordered responses and keeps working;
``stats`` reports the shed in ``mux``.

Phase 4 — deadlines. Restarts the server with ``--request-timeout``
set; a request carrying its own ``timeout_ms`` override that expires
while parked behind a slow sweep is answered with an in-band "timeout"
error instead of computing; ``stats`` reports it in ``mux``.

Usage: python3 ci/serve_smoke.py [path/to/tensordash]
"""

import json
import socket
import subprocess
import sys
import threading
import time

BIN = sys.argv[1] if len(sys.argv) > 1 else "target/release/tensordash"
HOST = "127.0.0.1"
PORT = 17871

CLIENTS = 6
# Two overlapping sweeps (the two-model sweep's gcn cells are the
# one-model sweep's whole unit set), alternated per client.
SWEEPS = [
    {"op": "sweep", "models": ["alexnet", "gcn"], "samples": 1, "seed": 42},
    {"op": "sweep", "models": ["gcn"], "samples": 1, "seed": 42},
]
REQS_PER_CLIENT = 4


def wait_for_port(proc, port, timeout=60.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if proc.poll() is not None:
            raise SystemExit(f"server exited early with code {proc.returncode}")
        try:
            with socket.create_connection((HOST, port), timeout=1.0):
                return
        except OSError:
            time.sleep(0.2)
    raise SystemExit("server never opened its port")


def roundtrip(payload, port):
    """One-shot connection: send one request object, return the parsed
    response line."""
    with socket.create_connection((HOST, port), timeout=120.0) as sock:
        sock.sendall((json.dumps(payload) + "\n").encode())
        with sock.makefile("r", encoding="utf-8") as f:
            line = f.readline()
    if not line:
        raise SystemExit("connection closed without a response")
    return json.loads(line)


def start_server(port, extra):
    proc = subprocess.Popen(
        [BIN, "serve", "--listen", f"{HOST}:{port}", "--jobs", "2"] + extra,
        stdout=subprocess.PIPE,
        stderr=subprocess.PIPE,
    )
    wait_for_port(proc, port)
    return proc


def stop_server(proc, port):
    bye = roundtrip({"op": "shutdown"}, port)
    assert bye.get("bye") is True, f"no shutdown ack: {bye}"
    code = proc.wait(timeout=60)
    assert code == 0, f"server exited with code {code}"


def run_client(client, port):
    """One persistent connection, REQS_PER_CLIENT sequential requests
    with ids; returns the report bodies in request order."""
    bodies = []
    with socket.create_connection((HOST, port), timeout=120.0) as sock:
        with sock.makefile("r", encoding="utf-8") as f:
            for i in range(REQS_PER_CLIENT):
                req = dict(SWEEPS[i % len(SWEEPS)])
                req["id"] = f"c{client}-r{i}"
                sock.sendall((json.dumps(req) + "\n").encode())
                line = f.readline()
                assert line, f"client {client}: connection closed mid-stream"
                resp = json.loads(line)
                assert resp.get("ok") is True, f"client {client} req {i}: {resp}"
                assert resp.get("id") == req["id"], (
                    f"client {client}: response out of order: {resp.get('id')}"
                )
                bodies.append(json.dumps(resp["report"]))
    return bodies


def phase_concurrent_load():
    proc = start_server(
        PORT, ["--workers", "4", "--queue-depth", "32", "--shards", "16"]
    )
    try:
        results = [None] * CLIENTS
        errors = []

        def fire(i):
            try:
                results[i] = run_client(i, PORT)
            except Exception as e:  # noqa: BLE001 - report, don't hang
                errors.append(f"client {i}: {e}")

        threads = [threading.Thread(target=fire, args=(i,)) for i in range(CLIENTS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
        if errors:
            raise SystemExit("; ".join(errors))

        # Byte-identical bodies across every client (json.dumps
        # preserves the server's key order).
        for i, bodies in enumerate(results):
            assert bodies is not None, f"client {i} returned nothing"
            assert len(bodies) == REQS_PER_CLIENT, f"client {i} lost responses"
            assert bodies == results[0], f"client {i} diverged from client 0"
        print(
            f"ok: {CLIENTS} concurrent clients x {REQS_PER_CLIENT} overlapping "
            "sweeps returned byte-identical bodies in request order"
        )

        # Duplicate sub-requests in one batch must coalesce onto one
        # computation (fresh seed so the units cannot already be
        # cached).
        batch = {
            "op": "batch",
            "requests": [
                {"op": "simulate", "id": "a", "model": "gcn", "samples": 1, "seed": 777},
                {"op": "simulate", "id": "b", "model": "gcn", "samples": 1, "seed": 777},
            ],
        }
        with socket.create_connection((HOST, PORT), timeout=120.0) as sock:
            sock.sendall((json.dumps(batch) + "\n").encode())
            with sock.makefile("r", encoding="utf-8") as f:
                lines = [f.readline(), f.readline()]
        subs = [json.loads(l) for l in lines]
        assert all(r.get("ok") is True for r in subs), f"batch failed: {subs}"
        assert json.dumps(subs[0]["report"]) == json.dumps(subs[1]["report"])
        coalesced = subs[-1].get("cache", {}).get("coalesced", 0)
        assert coalesced > 0, f"batch duplicates did not coalesce: {subs[-1]}"
        print(f"ok: duplicate batch sub-requests coalesced ({coalesced} units)")

        # Cumulative stats: real cache traffic over the configured
        # shard count.
        stats = roundtrip({"op": "stats"}, PORT)
        assert stats.get("ok") is True, f"stats not ok: {stats}"
        total = stats["cache"]
        assert total["inserts"] > 0, f"no units were ever computed: {total}"
        assert total["hits"] > 0, f"no request was ever cache-served: {total}"
        assert total["coalesced"] > 0, f"coalescing telemetry lost: {total}"
        assert stats.get("cache_shards") == 16, f"shard count not reported: {stats}"
        print(
            "ok: cumulative telemetry hits={hits} misses={misses} "
            "inserts={inserts} coalesced={coalesced} over 16 shards".format(**total)
        )

        stop_server(proc, PORT)
        print("ok: clean shutdown under load config (exit 0)")
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=10)


# A multi-model cold sweep: seconds of compute, so requests parked
# behind it have ample time to be raced, shed or timed out.
SLOW_SWEEP = {
    "op": "sweep",
    "models": ["alexnet", "gcn"],
    "epochs": [0.1, 0.5, 0.9],
    "samples": 2,
    "seed": 97,
    "id": "slow",
}

FAST_POINT = {"op": "simulate", "model": "gcn", "epoch": 0.5, "samples": 1, "seed": 4242}


def send_req(sock, payload):
    sock.sendall((json.dumps(payload) + "\n").encode())


def phase_head_of_line():
    port = PORT + 1
    proc = start_server(port, ["--workers", "2"])
    try:
        # Reference body via a plain v1 roundtrip (also warms the
        # cache, so the streamed copies below are cache hits).
        ref = roundtrip(FAST_POINT, port)
        assert ref.get("ok") is True, f"reference request failed: {ref}"
        ref_body = json.dumps(ref["report"])

        with socket.create_connection((HOST, port), timeout=120.0) as sock:
            with sock.makefile("r", encoding="utf-8") as f:
                send_req(sock, SLOW_SWEEP)
                time.sleep(0.3)  # let a worker dequeue the sweep
                for i in range(3):
                    req = dict(FAST_POINT)
                    req["id"] = f"f{i}"
                    req["stream"] = True
                    send_req(sock, req)
                seen = []
                for _ in range(3):
                    resp = json.loads(f.readline())
                    assert resp.get("ok") is True, f"fast request failed: {resp}"
                    assert resp.get("op") == "simulate", f"no op echo: {resp}"
                    assert json.dumps(resp["report"]) == ref_body, (
                        f"streamed body diverged: {resp.get('id')}"
                    )
                    seen.append(resp.get("id"))
                assert sorted(seen) == ["f0", "f1", "f2"], (
                    f"fast requests did not all overtake the sweep: {seen}"
                )
                slow = json.loads(f.readline())
                assert slow.get("id") == "slow", f"expected the sweep last: {slow}"
                assert slow.get("ok") is True, f"sweep failed: {slow}"
                assert "op" not in slow, f"ordered v1 reply grew an op echo: {slow}"
        print("ok: 3 streamed fast requests overtook a slow sweep on one connection")
        stop_server(proc, port)
        print("ok: clean shutdown under head-of-line config (exit 0)")
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=10)


def phase_backpressure():
    port = PORT + 2
    proc = start_server(port, ["--workers", "1", "--queue-depth", "1"])
    try:
        with socket.create_connection((HOST, port), timeout=120.0) as sock:
            with sock.makefile("r", encoding="utf-8") as f:
                # Occupy the single worker with the slow sweep ...
                send_req(sock, SLOW_SWEEP)
                time.sleep(0.3)  # let the worker dequeue it
                # ... park one ordered request in the depth-1 queue ...
                send_req(sock, {"op": "stats", "id": "queued"})
                time.sleep(0.2)
                # ... so the next request is shed in-band: an immediate
                # out-of-order error on a connection that stays open.
                send_req(sock, {"op": "stats", "id": "shed", "stream": True})
                shed = json.loads(f.readline())
                assert shed.get("id") == "shed", f"expected the shed reply first: {shed}"
                assert shed.get("ok") is False, f"shed response claims ok: {shed}"
                assert "overloaded" in shed.get("error", ""), f"not an overload error: {shed}"
                assert shed.get("op") == "stats", f"no op echo on the shed reply: {shed}"
                print(f"ok: queue overflow shed in-band: {shed['error']}")

                # The connection survived the shed: its ordered
                # responses still arrive, strictly in request order.
                for want in ["slow", "queued"]:
                    resp = json.loads(f.readline())
                    assert resp.get("id") == want, f"order broken: {resp}"
                    assert resp.get("ok") is True, f"{want} failed: {resp}"
                print("ok: connection stayed open and v1 order held after the shed")

                # The shed is visible in the mux telemetry.
                send_req(sock, {"op": "stats", "id": "after"})
                stats = json.loads(f.readline())
                assert stats.get("ok") is True, f"post-shed stats failed: {stats}"
                assert stats["mux"]["shed"] >= 1, f"shed not counted: {stats['mux']}"
                print("ok: stats report mux shed={}".format(stats["mux"]["shed"]))

        stop_server(proc, port)
        print("ok: clean shutdown under backpressure config (exit 0)")
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=10)


def phase_request_timeout():
    port = PORT + 3
    # A server-wide default deadline nothing here will hit (it also
    # exercises the flag), overridden per-request below.
    proc = start_server(port, ["--workers", "1", "--request-timeout", "3600000"])
    try:
        with socket.create_connection((HOST, port), timeout=120.0) as sock:
            with sock.makefile("r", encoding="utf-8") as f:
                send_req(sock, SLOW_SWEEP)
                time.sleep(0.3)
                # Parked behind the sweep with a 1ms budget: expired
                # long before a worker reaches it.
                send_req(
                    sock,
                    {"op": "stats", "id": "late", "timeout_ms": 1, "stream": True},
                )
                slow = json.loads(f.readline())
                assert slow.get("id") == "slow", f"expected the sweep first: {slow}"
                assert slow.get("ok") is True, f"sweep failed: {slow}"
                late = json.loads(f.readline())
                assert late.get("id") == "late", f"expected the timeout next: {late}"
                assert late.get("ok") is False, f"expired request claims ok: {late}"
                assert "timeout" in late.get("error", ""), f"not a timeout error: {late}"
                print(f"ok: queued past its deadline, answered in-band: {late['error']}")

                send_req(sock, {"op": "stats", "id": "after"})
                stats = json.loads(f.readline())
                assert stats.get("ok") is True, f"post-timeout stats failed: {stats}"
                assert stats["mux"]["timeouts"] >= 1, f"timeout not counted: {stats['mux']}"
                print("ok: stats report mux timeouts={}".format(stats["mux"]["timeouts"]))

        stop_server(proc, port)
        print("ok: clean shutdown under deadline config (exit 0)")
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=10)


def main():
    phase_concurrent_load()
    phase_head_of_line()
    phase_backpressure()
    phase_request_timeout()
    print("serve smoke: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
