#!/usr/bin/env python3
"""Experiment-store e2e smoke test.

Drives the ``store`` subcommand end to end with the release binary:

* simulate the same model at two epochs (standing in for two commits),
  ingest both JSON reports into one ``.tdstore`` file;
* ``store query`` returns the catalog and a metric trajectory as
  parseable ``tensordash.report.v1`` JSON with the expected rows;
* a repeated query in a *fresh process* is byte-identical on stdout
  (the store is deterministic across processes, not just in-process);
* re-ingesting an identical file is idempotent: zero new records and
  zero file growth;
* ``store diff`` between the two commits reports per-metric deltas;
* ingesting a document with an unknown schema fails loudly (typed
  error, non-zero exit), and querying a missing store file fails
  instead of creating it.

Usage: python3 ci/store_smoke.py [path/to/tensordash]
"""

import json
import os
import subprocess
import sys
import tempfile

BIN = sys.argv[1] if len(sys.argv) > 1 else "target/release/tensordash"


def run(args, expect_ok=True):
    """Run the binary, return (stdout, stderr). Asserts the exit code."""
    proc = subprocess.run(
        [BIN] + args, capture_output=True, text=True, timeout=600
    )
    if expect_ok and proc.returncode != 0:
        raise SystemExit(
            f"command {args} exited {proc.returncode}\nstderr:\n{proc.stderr}"
        )
    if not expect_ok and proc.returncode == 0:
        raise SystemExit(f"command {args} unexpectedly succeeded\nstdout:\n{proc.stdout}")
    return proc.stdout, proc.stderr


def reports_of(stdout):
    """Parse a reportset/report JSON rendering into a list of reports."""
    doc = json.loads(stdout)
    if doc.get("schema") == "tensordash.reportset.v1":
        return doc["reports"]
    return [doc]


def main():
    tmp = tempfile.mkdtemp(prefix="td_store_smoke_")
    db = os.path.join(tmp, "experiments.tdstore")
    sim_a = os.path.join(tmp, "sim_a.json")
    sim_b = os.path.join(tmp, "sim_b.json")

    # Two runs of the same experiment at different "commits".
    base = ["simulate", "--model", "gcn", "--samples", "1", "--seed", "42", "--format", "json"]
    run(base + ["--epoch", "0.1", "--out", sim_a])
    run(base + ["--epoch", "0.9", "--out", sim_b])
    print("ok: simulated gcn at two epochs")

    # Ingest both; the second file is a different config hash + commit.
    _, err = run(["store", "ingest", "--db", db, "--commit", "c1", sim_a])
    assert "1 new record(s)" in err, f"first ingest not recorded: {err}"
    _, err = run(["store", "ingest", "--db", db, "--commit", "c2", sim_b])
    assert "2 total" in err, f"second ingest missing: {err}"
    print("ok: ingested two commits into one store file")

    # Catalog: one row per record, both commits present.
    out, _ = run(["store", "query", "--db", db, "--format", "json"])
    (catalog,) = reports_of(out)
    assert catalog["id"] == "store_query", catalog["id"]
    commits = [row["cells"][0]["text"] for row in catalog["rows"]]
    assert commits == ["c1", "c2"], f"catalog commits: {commits}"

    # Trajectory: the overall speedup of the 'speedup' row across
    # commits, in ingestion order.
    traj_cmd = [
        "store", "query", "--db", db,
        "--metric", "overall", "--model", "speedup", "--format", "json",
    ]
    out, _ = run(traj_cmd)
    (traj,) = reports_of(out)
    assert len(traj["rows"]) == 2, f"trajectory rows: {traj['rows']}"
    values = [row["cells"][3]["value"] for row in traj["rows"]]
    assert all(v > 1.0 for v in values), f"speedups not extracted: {values}"
    print(f"ok: trajectory across commits = {values}")

    # Cross-process determinism: a fresh process, byte-identical stdout.
    repeat, _ = run(traj_cmd)
    assert repeat == out, "repeated query diverged across processes"
    print("ok: repeated query byte-identical in a fresh process")

    # Idempotent re-ingest: no new records, no file growth.
    size_before = os.path.getsize(db)
    _, err = run(["store", "ingest", "--db", db, "--commit", "c1", sim_a])
    assert "0 new record(s)" in err, f"re-ingest was not idempotent: {err}"
    assert os.path.getsize(db) == size_before, "idempotent re-ingest grew the file"
    print("ok: re-ingest idempotent (0 new records, 0 bytes growth)")

    # Diff: per-metric deltas between the two commits.
    out, _ = run(["store", "diff", "--db", db, "--id", "simulate",
                  "--from", "c1", "--to", "c2", "--format", "json"])
    (diff,) = reports_of(out)
    assert diff["id"] == "store_diff", diff["id"]
    assert diff["meta"]["metrics_compared"] > 0, diff["meta"]
    assert diff["rows"], "diff produced no rows"
    print(f"ok: diff compared {diff['meta']['metrics_compared']:g} metrics")

    # Unknown schemas are a typed error, not a silent skip.
    bogus = os.path.join(tmp, "bogus.json")
    with open(bogus, "w", encoding="utf-8") as f:
        f.write('{"schema":"tensordash.mystery.v9","rows":[]}\n')
    _, err = run(["store", "ingest", "--db", db, "--commit", "c3", bogus], expect_ok=False)
    assert "tensordash.mystery.v9" in err, f"unknown schema not named: {err}"
    print("ok: unknown schema rejected loudly")

    # Query must not invent a store file.
    missing = os.path.join(tmp, "nope.tdstore")
    _, err = run(["store", "query", "--db", missing], expect_ok=False)
    assert not os.path.exists(missing), "query created a store file"
    print("ok: query refuses to create a missing store")

    print("store smoke: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
