#!/usr/bin/env python3
"""Bench-regression gate: check BENCH_*.json artifacts against the
floors committed in ci/bench_floors.json.

Usage:
    python3 ci/check_bench_floors.py BENCH_scheduler.json BENCH_tile.json ...

Every artifact named on the command line must exist, parse as a
``tensordash.bench.v1`` document, and satisfy every floor registered
for it. Floor kinds:

* ``min_speedup``  — the ``speedup`` field of every record whose name
  matches the pattern must be >= the floor;
* ``max_median_ns`` — the ``median_ns`` field of every matching record
  must be <= the ceiling.

Patterns are ``fnmatch`` globs. A pattern that matches no record fails
the gate: renaming a record must not silently remove its floor.
Exit code 0 = all floors hold; 1 = any violation.
"""

import fnmatch
import json
import os
import sys

FLOORS_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "bench_floors.json")


def fail(msg: str) -> None:
    print(f"FLOOR VIOLATION: {msg}")
    fail.count += 1


fail.count = 0


def records_by_name(doc: dict) -> dict:
    if doc.get("schema") != "tensordash.bench.v1":
        raise SystemExit(f"unexpected bench schema: {doc.get('schema')!r}")
    out = {}
    for rec in doc.get("records", []):
        name = rec.get("name")
        if name:
            out[name] = rec
    return out


def matching(records: dict, pattern: str) -> list:
    return [records[name] for name in sorted(records) if fnmatch.fnmatch(name, pattern)]


def check_artifact(path: str, floors: dict) -> None:
    with open(path, encoding="utf-8") as f:
        records = records_by_name(json.load(f))
    print(f"== {path}: {len(records)} records")
    for pattern, floor in sorted(floors.get("min_speedup", {}).items()):
        recs = matching(records, pattern)
        if not recs:
            fail(f"{path}: no record matches min_speedup pattern '{pattern}'")
            continue
        for rec in recs:
            speedup = rec.get("speedup")
            if speedup is None:
                fail(f"{path}: record '{rec['name']}' has no 'speedup' field")
            elif speedup < floor:
                fail(f"{path}: {rec['name']} speedup {speedup:.3f}x < floor {floor}x")
            else:
                print(f"   ok  {rec['name']}: speedup {speedup:.3f}x >= {floor}x")
    for pattern, spec in sorted(floors.get("min_speedup_per_job", {}).items()):
        recs = matching(records, pattern)
        if not recs:
            fail(f"{path}: no record matches min_speedup_per_job pattern '{pattern}'")
            continue
        for rec in recs:
            speedup, jobs = rec.get("speedup"), rec.get("jobs")
            if speedup is None or jobs is None:
                fail(f"{path}: record '{rec['name']}' needs 'speedup' and 'jobs' fields")
                continue
            floor = min(spec["cap"], spec["per_job"] * jobs)
            if speedup < floor:
                fail(
                    f"{path}: {rec['name']} speedup {speedup:.3f}x < floor {floor:.2f}x "
                    f"({spec['per_job']}x/job at {jobs:g} jobs, cap {spec['cap']}x)"
                )
            else:
                print(f"   ok  {rec['name']}: speedup {speedup:.3f}x >= {floor:.2f}x")
    for pattern, ceiling in sorted(floors.get("max_median_ns", {}).items()):
        recs = matching(records, pattern)
        if not recs:
            fail(f"{path}: no record matches max_median_ns pattern '{pattern}'")
            continue
        for rec in recs:
            median = rec.get("median_ns")
            if median is None:
                fail(f"{path}: record '{rec['name']}' has no 'median_ns' field")
            elif median > ceiling:
                fail(
                    f"{path}: {rec['name']} median {median / 1e6:.3f} ms "
                    f"> ceiling {ceiling / 1e6:.3f} ms"
                )
            else:
                print(
                    f"   ok  {rec['name']}: median {median / 1e6:.3f} ms "
                    f"<= {ceiling / 1e6:.3f} ms"
                )


def main(argv: list) -> int:
    if len(argv) < 2:
        print(__doc__)
        return 2
    with open(FLOORS_PATH, encoding="utf-8") as f:
        config = json.load(f)
    if config.get("schema") != "tensordash.benchfloors.v1":
        raise SystemExit(f"unexpected floors schema: {config.get('schema')!r}")
    artifacts = config.get("artifacts", {})
    for path in argv[1:]:
        name = os.path.basename(path)
        if not os.path.exists(path):
            fail(f"artifact {path} is missing (bench did not run or write it)")
            continue
        floors = artifacts.get(name)
        if floors is None:
            fail(f"no floors registered for {name} in ci/bench_floors.json")
            continue
        check_artifact(path, floors)
    if fail.count:
        print(f"\n{fail.count} floor violation(s)")
        return 1
    print("\nall bench floors hold")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
