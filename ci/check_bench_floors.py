#!/usr/bin/env python3
"""Bench-regression gate: check BENCH_*.json artifacts against the
floors committed in ci/bench_floors.json.

Usage:
    python3 ci/check_bench_floors.py BENCH_scheduler.json BENCH_tile.json ...
    python3 ci/check_bench_floors.py --store experiments.tdstore

Every artifact named on the command line must exist, parse as a
``tensordash.bench.v1`` document, and satisfy every floor registered
for it. Floor kinds:

* ``min_speedup``  — the ``speedup`` field of every record whose name
  matches the pattern must be >= the floor;
* ``max_median_ns`` — the ``median_ns`` field of every matching record
  must be <= the ceiling;
* ``require_identical`` — every matching record must carry
  ``"identical": true``, the bench's in-run assertion that the fast
  path produced byte-identical results to the reference it was raced
  against. A speedup record without that flag means the bench dropped
  its equality check, so the gate fails.

Patterns are ``fnmatch`` globs. A pattern that matches no record fails
the gate: renaming a record must not silently remove its floor.

``--store FILE`` reads the bench documents out of a ``.tdstore``
experiment-store file instead (the record log written by ``tensordash
store ingest``; format in DESIGN.md §store). Each configured artifact's
``bench`` field names its record group inside the store; every stored
document of that bench is held to the artifact's floors.

Exit code 0 = all floors hold; 1 = any violation.
"""

import fnmatch
import json
import os
import sys

FLOORS_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "bench_floors.json")

STORE_MAGIC = b"TDSTORE1"
STORE_VERSION = 1
KIND_RECORD = 1
KIND_INDEX = 2
MIN_BODY = 21  # kind u8 + key_hash u64 + key_len u32 + checksum u64


def fail(msg: str) -> None:
    print(f"FLOOR VIOLATION: {msg}")
    fail.count += 1


fail.count = 0


def read_store_docs(path: str) -> list:
    """Walk a .tdstore record log and return the live stored documents.

    Frame layout (little-endian, see rust/src/store/log.rs): a 16-byte
    header (magic + version), then u32-length-prefixed frames with body
    ``kind u8 | key_hash u64 | key_len u32 | key | payload | checksum
    u64``. Index frames and the trailer are skipped; duplicate keys are
    last-wins, mirroring the rust reader. A torn tail simply ends the
    walk — exactly the rust crash-recovery rule.
    """
    with open(path, "rb") as f:
        blob = f.read()
    if len(blob) < 16 or blob[:8] != STORE_MAGIC:
        raise SystemExit(f"{path}: not a {STORE_MAGIC.decode()} record log")
    version = int.from_bytes(blob[8:16], "little")
    if version != STORE_VERSION:
        raise SystemExit(f"{path}: unsupported record-log version {version}")
    by_key = {}
    pos = 16
    while pos + 4 <= len(blob):
        length = int.from_bytes(blob[pos : pos + 4], "little")
        frame_end = pos + 4 + length
        if length < MIN_BODY or frame_end > len(blob):
            break  # trailer or torn tail
        body = blob[pos + 4 : frame_end]
        pos = frame_end
        kind = body[0]
        if kind == KIND_INDEX:
            continue
        if kind != KIND_RECORD:
            break
        key_len = int.from_bytes(body[9:13], "little")
        payload = body[13 + key_len : -8]
        try:
            env = json.loads(payload.decode("utf-8"))
        except (UnicodeDecodeError, ValueError):
            continue
        if env.get("schema") != "tensordash.store.v1":
            continue
        key = env.get("key")
        if key is not None and "doc" in env:
            by_key[key] = env["doc"]  # last-wins, insertion-ordered
    return list(by_key.values())


def records_by_name(doc: dict) -> dict:
    if doc.get("schema") != "tensordash.bench.v1":
        raise SystemExit(f"unexpected bench schema: {doc.get('schema')!r}")
    out = {}
    for rec in doc.get("records", []):
        name = rec.get("name")
        if name:
            out[name] = rec
    return out


def matching(records: dict, pattern: str) -> list:
    return [records[name] for name in sorted(records) if fnmatch.fnmatch(name, pattern)]


def check_doc(label: str, doc: dict, floors: dict) -> None:
    records = records_by_name(doc)
    print(f"== {label}: {len(records)} records")
    for pattern, floor in sorted(floors.get("min_speedup", {}).items()):
        recs = matching(records, pattern)
        if not recs:
            fail(f"{label}: no record matches min_speedup pattern '{pattern}'")
            continue
        for rec in recs:
            speedup = rec.get("speedup")
            if speedup is None:
                fail(f"{label}: record '{rec['name']}' has no 'speedup' field")
            elif speedup < floor:
                fail(f"{label}: {rec['name']} speedup {speedup:.3f}x < floor {floor}x")
            else:
                print(f"   ok  {rec['name']}: speedup {speedup:.3f}x >= {floor}x")
    for pattern, spec in sorted(floors.get("min_speedup_per_job", {}).items()):
        recs = matching(records, pattern)
        if not recs:
            fail(f"{label}: no record matches min_speedup_per_job pattern '{pattern}'")
            continue
        for rec in recs:
            speedup, jobs = rec.get("speedup"), rec.get("jobs")
            if speedup is None or jobs is None:
                fail(f"{label}: record '{rec['name']}' needs 'speedup' and 'jobs' fields")
                continue
            floor = min(spec["cap"], spec["per_job"] * jobs)
            if speedup < floor:
                fail(
                    f"{label}: {rec['name']} speedup {speedup:.3f}x < floor {floor:.2f}x "
                    f"({spec['per_job']}x/job at {jobs:g} jobs, cap {spec['cap']}x)"
                )
            else:
                print(f"   ok  {rec['name']}: speedup {speedup:.3f}x >= {floor:.2f}x")
    for pattern, ceiling in sorted(floors.get("max_median_ns", {}).items()):
        recs = matching(records, pattern)
        if not recs:
            fail(f"{label}: no record matches max_median_ns pattern '{pattern}'")
            continue
        for rec in recs:
            median = rec.get("median_ns")
            if median is None:
                fail(f"{label}: record '{rec['name']}' has no 'median_ns' field")
            elif median > ceiling:
                fail(
                    f"{label}: {rec['name']} median {median / 1e6:.3f} ms "
                    f"> ceiling {ceiling / 1e6:.3f} ms"
                )
            else:
                print(
                    f"   ok  {rec['name']}: median {median / 1e6:.3f} ms "
                    f"<= {ceiling / 1e6:.3f} ms"
                )
    for pattern in sorted(floors.get("require_identical", [])):
        recs = matching(records, pattern)
        if not recs:
            fail(f"{label}: no record matches require_identical pattern '{pattern}'")
            continue
        for rec in recs:
            if rec.get("identical") is not True:
                fail(
                    f"{label}: record '{rec['name']}' does not assert byte-identity "
                    f"(identical != true)"
                )
            else:
                print(f"   ok  {rec['name']}: byte-identity asserted in-bench")


def check_artifact(path: str, floors: dict) -> None:
    with open(path, encoding="utf-8") as f:
        check_doc(path, json.load(f), floors)


def check_store(path: str, artifacts: dict) -> None:
    if not os.path.exists(path):
        fail(f"store file {path} is missing (ingest did not run or write it)")
        return
    docs = read_store_docs(path)
    bench_docs = [d for d in docs if isinstance(d, dict) and d.get("schema") == "tensordash.bench.v1"]
    print(f"store {path}: {len(docs)} live records, {len(bench_docs)} bench documents")
    for name in sorted(artifacts):
        floors = artifacts[name]
        bench = floors.get("bench")
        if bench is None:
            fail(f"{name}: no 'bench' name in ci/bench_floors.json (needed for --store)")
            continue
        matches = [d for d in bench_docs if d.get("bench") == bench]
        if not matches:
            fail(f"{path}: no stored bench document named '{bench}' (for {name})")
            continue
        for i, doc in enumerate(matches):
            check_doc(f"{path}[{bench}#{i}]", doc, floors)


def main(argv: list) -> int:
    if len(argv) < 2:
        print(__doc__)
        return 2
    with open(FLOORS_PATH, encoding="utf-8") as f:
        config = json.load(f)
    if config.get("schema") != "tensordash.benchfloors.v1":
        raise SystemExit(f"unexpected floors schema: {config.get('schema')!r}")
    artifacts = config.get("artifacts", {})
    if argv[1] == "--store":
        if len(argv) != 3:
            print(__doc__)
            return 2
        check_store(argv[2], artifacts)
    else:
        for path in argv[1:]:
            name = os.path.basename(path)
            if not os.path.exists(path):
                fail(f"artifact {path} is missing (bench did not run or write it)")
                continue
            floors = artifacts.get(name)
            if floors is None:
                fail(f"no floors registered for {name} in ci/bench_floors.json")
                continue
            check_artifact(path, floors)
    if fail.count:
        print(f"\n{fail.count} floor violation(s)")
        return 1
    print("\nall bench floors hold")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
