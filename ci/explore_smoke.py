#!/usr/bin/env python3
"""Explore e2e smoke test.

Runs ``tensordash explore`` with a tiny budget over a small space and
asserts, from the emitted ``tensordash.frontier.v1`` JSON:

* the frontier is non-empty and every row carries the expected columns;
* the explorer generated more than one generation and its survivor
  re-evaluations produced **nonzero unit-cache hits** (the cache-driven
  search contract);
* the staging-depth slice reproduced the fig-19 ordering
  (``depth_ordered`` meta flag — the binary itself also exits non-zero
  when the gate fails);
* a repeat run with the same seed produces a byte-identical report
  (fixed-seed determinism across processes).

Usage: python3 ci/explore_smoke.py [path/to/tensordash]
"""

import json
import os
import subprocess
import sys
import tempfile

BIN = sys.argv[1] if len(sys.argv) > 1 else "target/release/tensordash"

ARGS = [
    "explore",
    "--models", "alexnet",
    "--budget", "5",
    "--samples", "1",
    "--seed", "7",
    "--axis", "staging_depth=2,3",
    "--axis", "tile_rows=2,4",
    "--axis", "tile_cols=4,8",
    "--format", "json",
]


def run_explore(out_path):
    cmd = [BIN, *ARGS, "--out", out_path]
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=600)
    sys.stdout.write(proc.stderr)
    if proc.returncode != 0:
        raise SystemExit(f"explore exited with code {proc.returncode}")
    with open(out_path, encoding="utf-8") as f:
        return f.read()


def main():
    with tempfile.TemporaryDirectory() as tmp:
        first_path = os.path.join(tmp, "frontier_a.json")
        second_path = os.path.join(tmp, "frontier_b.json")
        first = run_explore(first_path)
        doc = json.loads(first)

        if doc.get("schema") != "tensordash.frontier.v1":
            raise SystemExit(f"unexpected schema: {doc.get('schema')!r}")
        rows = doc.get("rows", [])
        if not rows:
            raise SystemExit("frontier is empty")
        columns = doc.get("columns", [])
        if columns[:2] != ["config", "td cycles"]:
            raise SystemExit(f"unexpected columns: {columns!r}")
        meta = doc.get("meta", {})

        evaluations = meta.get("evaluations", 0)
        generations = meta.get("generations", 0)
        hits = meta.get("unit_cache_hits", 0)
        if evaluations < 5:
            raise SystemExit(f"expected 5 evaluations, got {evaluations}")
        if generations < 2:
            raise SystemExit(f"expected multiple generations, got {generations}")
        if hits <= 0:
            raise SystemExit(
                "expected nonzero unit-cache hits across generations "
                f"(survivor re-evaluation), got {hits}"
            )
        if meta.get("depth_ordered") != 1:
            raise SystemExit("fig-19 depth ordering gate not satisfied")
        print(
            f"ok: frontier of {len(rows)} rows from {evaluations} evaluations "
            f"over {generations} generations, {hits:g} cache hits, depth slice ordered"
        )

        second = run_explore(second_path)
        if first != second:
            raise SystemExit("repeated explore with the same seed is not byte-identical")
        print("ok: repeat run byte-identical")


if __name__ == "__main__":
    main()
