#!/usr/bin/env python3
"""Sparsity-regime scenario smoke: the transformer tier (``bert``) and
the N:M structured regime pushed through every user-facing surface.

* ``simulate`` — ``bert`` under ``uniform``, ``nm:2:4`` and a schedule
  curve; each run repeated and byte-compared (fixed-seed determinism
  across processes), and the structured run must move at least one
  reported number relative to uniform (the mask really bites);
* CLI validation — ``--epoch 1.5`` and ``--regime nm:4:2`` fail fast
  with the exact ``api::params`` wording the serve path uses;
* ``serve`` — the same three regimes as JSON-lines requests over TCP,
  byte-identical repeats, regime-distinct bodies, clean shutdown;
* ``explore`` — a tiny-budget search over ``bert`` under ``nm:2:4``,
  frontier stamped with the regime, byte-identical repeat;
* ``info`` — the self-documenting surface lists the transformer tier
  and every regime spelling.

Usage: python3 ci/scenario_smoke.py [path/to/tensordash]
"""

import json
import os
import socket
import subprocess
import sys
import tempfile
import time

BIN = sys.argv[1] if len(sys.argv) > 1 else "target/release/tensordash"
HOST = "127.0.0.1"
PORT = 17879

REGIMES = ["uniform", "nm:2:4", "schedule:pruned-reclaim:0.3"]


def run(args, expect_ok=True, timeout=600):
    proc = subprocess.run([BIN, *args], capture_output=True, text=True, timeout=timeout)
    if expect_ok and proc.returncode != 0:
        raise SystemExit(
            f"{' '.join(args)} exited with code {proc.returncode}:\n{proc.stderr}"
        )
    return proc


def simulate(regime, out_path):
    run(
        [
            "simulate", "--model", "bert", "--epoch", "0.4", "--samples", "1",
            "--seed", "7", "--regime", regime, "--format", "json", "--out", out_path,
        ]
    )
    with open(out_path, encoding="utf-8") as f:
        return f.read()


def check_simulate(tmp):
    bodies = {}
    for i, regime in enumerate(REGIMES):
        a = simulate(regime, os.path.join(tmp, f"sim_{i}_a.json"))
        b = simulate(regime, os.path.join(tmp, f"sim_{i}_b.json"))
        if a != b:
            raise SystemExit(f"simulate --regime {regime} rerun is not byte-identical")
        doc = json.loads(a)
        if doc.get("schema") != "tensordash.report.v1":
            raise SystemExit(f"unexpected schema: {doc.get('schema')!r}")
        bodies[regime] = a
    if bodies["uniform"] == bodies["nm:2:4"]:
        raise SystemExit("nm:2:4 produced the same report as uniform — mask not applied")
    print("ok: simulate bert under all regimes, byte-identical reruns, nm bites")


def check_cli_wording():
    cases = [
        (["simulate", "--model", "bert", "--epoch", "1.5"],
         "--epoch must be within [0, 1]"),
        (["simulate", "--model", "bert", "--regime", "nm:4:2"],
         "--regime nm requires n <= m"),
        (["explore", "--models", "bert", "--epoch", "-0.1"],
         "--epoch must be within [0, 1]"),
    ]
    for args, wording in cases:
        proc = run(args, expect_ok=False)
        if proc.returncode == 0:
            raise SystemExit(f"{' '.join(args)} should have failed")
        if wording not in proc.stderr:
            raise SystemExit(
                f"{' '.join(args)}: expected {wording!r} in stderr, got:\n{proc.stderr}"
            )
    print("ok: CLI rejects bad epoch/regime with the shared params wording")


def wait_for_port(proc, port, timeout=60.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if proc.poll() is not None:
            raise SystemExit(f"server exited early with code {proc.returncode}")
        try:
            with socket.create_connection((HOST, port), timeout=1.0):
                return
        except OSError:
            time.sleep(0.2)
    raise SystemExit("server never opened its port")


def roundtrip(payload, port):
    with socket.create_connection((HOST, port), timeout=300.0) as sock:
        sock.sendall((json.dumps(payload) + "\n").encode())
        with sock.makefile("r", encoding="utf-8") as f:
            line = f.readline()
    if not line:
        raise SystemExit(f"no response for {payload!r}")
    return json.loads(line)


def check_serve():
    server = subprocess.Popen(
        [BIN, "serve", "--listen", f"{HOST}:{PORT}", "--jobs", "4", "--preload", "bert"],
        stdout=subprocess.DEVNULL,
        stderr=subprocess.PIPE,
        text=True,
    )
    try:
        wait_for_port(server, PORT)
        bodies = {}
        for i, regime in enumerate(REGIMES):
            req = {
                "op": "simulate", "id": f"r{i}", "model": "bert", "epoch": 0.4,
                "samples": 1, "seed": 7, "regime": regime,
            }
            first = roundtrip(req, PORT)
            if first.get("ok") is not True:
                raise SystemExit(f"serve rejected {regime}: {first!r}")
            again = roundtrip(req, PORT)
            if first.get("report") != again.get("report"):
                raise SystemExit(f"serve repeat for {regime} is not byte-identical")
            bodies[regime] = json.dumps(first.get("report"), sort_keys=True)
        if bodies["uniform"] == bodies["nm:2:4"]:
            raise SystemExit("serve: nm:2:4 body matches uniform — regime not threaded")
        bad = roundtrip({"op": "simulate", "model": "bert", "regime": "nm:4:2"}, PORT)
        if bad.get("ok") is not False or bad.get("error") != "'regime' nm requires n <= m":
            raise SystemExit(f"serve accepted a bad regime or reworded the error: {bad!r}")
        done = roundtrip({"op": "shutdown"}, PORT)
        if done.get("bye") is not True:
            raise SystemExit(f"shutdown not acknowledged: {done!r}")
        if server.wait(timeout=60) != 0:
            raise SystemExit(f"server exited with code {server.returncode}")
    finally:
        if server.poll() is None:
            server.kill()
            server.wait()
    print("ok: serve ran bert under all regimes with byte-identical repeats + clean shutdown")


def check_explore(tmp):
    args = [
        "explore", "--models", "bert", "--budget", "3", "--samples", "1",
        "--seed", "7", "--regime", "nm:2:4",
        "--axis", "staging_depth=2,3", "--axis", "tile_rows=2,4",
        "--format", "json",
    ]
    outs = []
    for tag in ("a", "b"):
        out_path = os.path.join(tmp, f"frontier_{tag}.json")
        run([*args, "--out", out_path])
        with open(out_path, encoding="utf-8") as f:
            outs.append(f.read())
    if outs[0] != outs[1]:
        raise SystemExit("explore rerun with the same seed is not byte-identical")
    doc = json.loads(outs[0])
    if doc.get("schema") != "tensordash.frontier.v1":
        raise SystemExit(f"unexpected schema: {doc.get('schema')!r}")
    if not doc.get("rows"):
        raise SystemExit("frontier is empty")
    if doc.get("meta", {}).get("regime") != "nm:2:4":
        raise SystemExit(f"frontier not stamped with the regime: {doc.get('meta')!r}")
    print("ok: explore searched bert under nm:2:4, stamped + byte-identical rerun")


def check_info():
    proc = run(["info"])
    out = proc.stdout
    for needle in ("bert", "transformer tier", "nm:N:M", "schedule:piecewise"):
        if needle not in out:
            raise SystemExit(f"info output is missing {needle!r}")
    print("ok: info lists the transformer tier and every regime spelling")


def main():
    with tempfile.TemporaryDirectory() as tmp:
        check_info()
        check_cli_wording()
        check_simulate(tmp)
        check_explore(tmp)
        check_serve()
    print("scenario smoke passed")


if __name__ == "__main__":
    main()
