"""Layer-2 building blocks: the paper's three training convolutions.

Each of the three major computations of one training step (paper §2,
Eq.(1)-(3) and Table 1) is lowered to the Layer-1 Pallas matmul kernel via
im2col, so that the innermost reduction is over 16-channel lanes — the
exact value stream a TensorDash PE consumes:

  * ``conv_fwd``   — Eq.(4):  O   = A ★ W
  * ``conv_igrad`` — Eq.(6):  G_A = G_O(dilated) ★ rot180(W)^T
  * ``conv_wgrad`` — Eq.(8):  G_W = G_O ★ A   (reduction over batch+space)

All tensors are NHWC / HWIO with channel innermost (the §3.4 16x16 group
layout keeps 16 channel-contiguous values per group; every channel count
in the model is a multiple of 16).
"""

import jax.numpy as jnp

from .kernels import matmul16


def _im2col(x, kh: int, kw: int, stride: int, padding: int):
    """Extract conv patches: (N,H,W,C) -> (N*OH*OW, KH*KW*C), ky-major."""
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w + 2 * padding - kw) // stride + 1
    cols = []
    for ky in range(kh):
        for kx in range(kw):
            sl = xp[:, ky : ky + (oh - 1) * stride + 1 : stride,
                       kx : kx + (ow - 1) * stride + 1 : stride, :]
            cols.append(sl)
    # (N, OH, OW, KH*KW, C) with (ky,kx) major, channel innermost.
    patches = jnp.stack(cols, axis=3)
    return patches.reshape(n * oh * ow, kh * kw * c), (n, oh, ow)


def conv_fwd(x, w, *, stride: int, padding: int):
    """Forward convolution, Eq.(4). x:(N,H,W,C) w:(KH,KW,C,F) -> (N,OH,OW,F)."""
    kh, kw, c, f = w.shape
    patches, (n, oh, ow) = _im2col(x, kh, kw, stride, padding)
    out = matmul16(patches, w.reshape(kh * kw * c, f))
    return out.reshape(n, oh, ow, f)


def _dilate_and_pad(g, *, stride: int, padding: int, kh: int, kw: int, input_hw):
    """Dilate gradients by the stride and pad for the 'full' convolution."""
    n, oh, ow, f = g.shape
    h, w = input_hw
    if stride > 1:
        gd = jnp.zeros((n, (oh - 1) * stride + 1, (ow - 1) * stride + 1, f), g.dtype)
        gd = gd.at[:, ::stride, ::stride, :].set(g)
    else:
        gd = g
    # After padding, a stride-1 valid conv with a KHxKW filter must produce
    # exactly (H, W) outputs.
    pt = kh - 1 - padding
    pl_ = kw - 1 - padding
    pb = h + kh - 1 - gd.shape[1] - pt
    pr = w + kw - 1 - gd.shape[2] - pl_
    return jnp.pad(gd, ((0, 0), (pt, pb), (pl_, pr), (0, 0)))


def conv_igrad(g, w, *, stride: int, padding: int, input_hw):
    """Input-gradient convolution, Eq.(6).

    g:(N,OH,OW,F), w:(KH,KW,C,F) -> (N,H,W,C). The filters are
    "reconstructed": rotated 180 degrees spatially and with the C/F roles
    swapped; the gradients are dilated by the forward stride.
    """
    kh, kw, c, f = w.shape
    gp = _dilate_and_pad(g, stride=stride, padding=padding, kh=kh, kw=kw,
                         input_hw=input_hw)
    w_rot = jnp.flip(w, (0, 1)).transpose(0, 1, 3, 2)  # (KH,KW,F,C)
    patches, (n, oh, ow) = _im2col(gp, kh, kw, 1, 0)
    out = matmul16(patches, w_rot.reshape(kh * kw * f, c))
    return out.reshape(n, oh, ow, c)


def conv_wgrad(x, g, *, stride: int, padding: int, kernel_hw):
    """Weight-gradient convolution, Eq.(8).

    x:(N,H,W,C), g:(N,OH,OW,F) -> (KH,KW,C,F). The reduction dimension of
    the matmul is batch x output-space — the paper's sum over si, xi, yi.
    """
    kh, kw = kernel_hw
    n, oh, ow, f = g.shape
    c = x.shape[3]
    patches, _ = _im2col(x, kh, kw, stride, padding)  # (N*OH*OW, KH*KW*C)
    gw = matmul16(patches.T, g.reshape(n * oh * ow, f))
    return gw.reshape(kh, kw, c, f)


def linear(x, w, b=None):
    """Fully-connected layer (paper Eq.(5)) through the Pallas kernel."""
    out = matmul16(x, w)
    return out if b is None else out + b[None, :]
