"""Blocked matmul Pallas kernel with a 16-wide reduction lane.

This is the compute hot-spot of the whole stack: all three training
convolutions of the paper (forward ``A*W`` Eq.(4), input-gradient
``G_O*W_rot`` Eq.(6) and weight-gradient ``G_O*A`` Eq.(8)) are lowered to
this kernel via im2col (see ``compile/convs.py``), exactly as the
TensorDash PE consumes them: dot products over blocks of 16
channel-contiguous values (the PE's 16 MAC lanes, paper §3.2).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the BlockSpec tiles the
(M,K)x(K,N) product into (bm,K)/(K,bn) VMEM-resident panels and iterates
the reduction in LANE=16 steps — the same HBM->VMEM schedule the paper
implements with AM/BM SRAM banks and 1KB scratchpads. On a real MXU the
inner ``a @ b`` becomes a systolic bf16 matmul; under interpret=True it is
numerically exact fp32, which is what the correctness oracle checks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The PE reduction width: 16 MAC lanes fed from 16-value channel blocks.
LANE = 16

# Default output tile. bm*K + K*bn + bm*bn fp32 values must fit the VMEM
# budget; for the model sizes used here the footprint is < 64 KiB/tile.
BLOCK_M = 32
BLOCK_N = 32


def _mm_kernel(a_ref, b_ref, o_ref, *, k_steps: int):
    """One (bm, bn) output tile: accumulate K in LANE-wide slabs."""

    def body(k, acc):
        a = a_ref[:, pl.dslice(k * LANE, LANE)]
        b = b_ref[pl.dslice(k * LANE, LANE), :]
        # 16 MACs per output element per step == one PE row (paper Fig. 6).
        return acc + jnp.dot(a, b, preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(
        0, k_steps, body, jnp.zeros(o_ref.shape, jnp.float32)
    )
    o_ref[...] = acc


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def matmul16(a, b, *, block_m: int = BLOCK_M, block_n: int = BLOCK_N):
    """``a @ b`` through the Pallas PE-style kernel.

    Arbitrary (M, K) x (K, N); inputs are zero-padded to multiples of the
    block shape (zero padding is exact for matmul) and the result sliced
    back. Accepts fp32; accumulation is fp32.
    """
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"matmul16 expects 2-D operands, got {a.shape} @ {b.shape}")
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape
    bm = min(block_m, _round_up(m, 8))
    bn = min(block_n, _round_up(n, 8))
    a = _pad_to(_pad_to(a, bm, 0), LANE, 1)
    b = _pad_to(_pad_to(b, LANE, 0), bn, 1)
    mp, kp = a.shape
    np_ = b.shape[1]
    out = pl.pallas_call(
        functools.partial(_mm_kernel, k_steps=kp // LANE),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((kp, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a, b)
    return out[:m, :n]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
