"""Zero-bitmap Pallas kernel — the staging buffer's zero detector.

The TensorDash staging buffer emits a 16-bit zero vector per row (paper
§3.2, the ``AZ``/``BZ`` inputs of the hardware scheduler). This kernel
computes those vectors for a whole tensor at once: the tensor is viewed as
``(groups, 16)`` (16 channel-contiguous values per group, matching the
16x16 layout of §3.4) and each group is packed into one int32 word with
bit ``l`` set iff lane ``l`` is NON-zero.

The AOT train-step artifact returns these bitmaps for every layer's
activations and gradients so the rust coordinator can drive the
cycle-accurate simulator without ever shipping full tensors.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import LANE

# Rows of 16-value groups handled per grid step.
BLOCK_G = 256


def _bitmap_kernel(x_ref, o_ref):
    x = x_ref[...]
    nz = (x != 0.0).astype(jnp.int32)
    weights = (2 ** jnp.arange(LANE, dtype=jnp.int32))[None, :]
    o_ref[...] = jnp.sum(nz * weights, axis=1)


def zero_bitmap16(x):
    """Pack non-zero lanes of ``x`` (viewed as (-1, 16)) into int32 words.

    ``x.size`` must be a multiple of 16 — the model keeps every channel
    dimension a multiple of 16 for exactly this reason (paper §3.4 layout).
    """
    flat = x.reshape(-1)
    if flat.shape[0] % LANE != 0:
        raise ValueError(f"tensor size {flat.shape[0]} not a multiple of {LANE}")
    groups = flat.shape[0] // LANE
    x2 = flat.reshape(groups, LANE)
    bg = min(BLOCK_G, groups)
    pad = (-groups) % bg
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    gp = x2.shape[0]
    out = pl.pallas_call(
        _bitmap_kernel,
        grid=(gp // bg,),
        in_specs=[pl.BlockSpec((bg, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bg,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((gp,), jnp.int32),
        interpret=True,
    )(x2)
    return out[:groups]
