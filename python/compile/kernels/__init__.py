"""Layer-1 Pallas kernels for the TensorDash compute stack.

Every kernel is written with a 16-wide innermost reduction lane to mirror
the TensorDash processing element (16 MACs/cycle over a 16-value channel
block, paper §3.2) and the 16x16 tensor-group memory layout (paper §3.4).

Kernels are lowered with ``interpret=True``: on the CPU PJRT plugin a real
TPU lowering would emit a Mosaic custom-call that cannot execute; the
interpret path lowers to plain HLO (a fori_loop over the grid) which runs
on any backend. Correctness is checked against ``ref.py`` by pytest.
"""

from .matmul import matmul16, LANE
from .bitmap import zero_bitmap16

__all__ = ["matmul16", "zero_bitmap16", "LANE"]
