"""Pure-jnp correctness oracles for every Pallas kernel and conv op.

Nothing here touches Pallas: these are the ground truth the pytest suite
checks the kernels (and the rust-visible HLO artifacts) against. The two
gradient convolutions are defined *by construction* as the VJP of the
forward convolution — exactly what the paper's Eq.(6) and Eq.(8) are the
closed forms of — so the oracle cannot share a bug with the kernels.
"""

import jax
import jax.numpy as jnp
from jax import lax


def matmul_ref(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def zero_bitmap_ref(x):
    x2 = x.reshape(-1, 16)
    nz = (x2 != 0.0).astype(jnp.int32)
    return jnp.sum(nz * (2 ** jnp.arange(16, dtype=jnp.int32))[None, :], axis=1)


def conv_fwd_ref(x, w, *, stride: int, padding: int):
    """Paper Eq.(4): NHWC x HWIO -> NHWC, explicit symmetric padding."""
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv_igrad_ref(g, w, *, stride: int, padding: int, input_shape):
    """Paper Eq.(6): dL/dx of the forward conv, via VJP (ground truth)."""
    x0 = jnp.zeros(input_shape, g.dtype)
    _, vjp = jax.vjp(lambda x: conv_fwd_ref(x, w, stride=stride, padding=padding), x0)
    return vjp(g)[0]


def conv_wgrad_ref(x, g, *, stride: int, padding: int, kernel_shape):
    """Paper Eq.(8): dL/dw of the forward conv, via VJP (ground truth)."""
    w0 = jnp.zeros(kernel_shape, x.dtype)
    _, vjp = jax.vjp(lambda w: conv_fwd_ref(x, w, stride=stride, padding=padding), w0)
    return vjp(g)[0]
