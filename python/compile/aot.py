"""AOT compile path: lower every computation the rust runtime needs to HLO TEXT.

Run once at build time (``make artifacts``); python never appears on the
request path. The interchange format is HLO *text*, not a serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids which
the ``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md). Everything is lowered with
``return_tuple=True`` and unwrapped with ``to_tuple`` on the rust side.

Artifacts:
  init.hlo.txt        (seed:i32[])                      -> params
  train_step.hlo.txt  (params..., x, y)                 -> params', loss, acc, bitmaps...
  conv_fwd.hlo.txt    (x, w)  at the conv-2 geometry    -> o          (Eq. 4)
  conv_igrad.hlo.txt  (g, w)  at the conv-2 geometry    -> g_in       (Eq. 6)
  conv_wgrad.hlo.txt  (x, g)  at the conv-2 geometry    -> g_w        (Eq. 8)
  matmul.hlo.txt      (a:f32[64,64], b:f32[64,64])      -> a@b
  bitmap.hlo.txt      (x:f32[256,16])                   -> i32[256]
  meta.json           shapes + calling convention for the rust runtime
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .convs import conv_fwd, conv_igrad, conv_wgrad
from .kernels import matmul16, zero_bitmap16
from .model import CFG, init_params, train_step_flat


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_meta(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def export_all(out_dir: str) -> None:
    cfg = CFG
    os.makedirs(out_dir, exist_ok=True)
    n, h, w, c0 = cfg.batch, cfg.height, cfg.width, cfg.in_channels
    param_shapes = [(k, k, cin, cout) for (k, _, _, cin, cout) in cfg.convs]
    param_shapes += [(cfg.flat_dim(), cfg.classes), (cfg.classes,)]
    out_hw = cfg.conv_out_hw()

    artifacts = {}

    # --- init -------------------------------------------------------------
    lowered = jax.jit(lambda seed: init_params(seed, cfg)).lower(
        _spec((), jnp.int32)
    )
    artifacts["init"] = to_hlo_text(lowered)

    # --- train step ---------------------------------------------------------
    arg_specs = [_spec(s) for s in param_shapes]
    arg_specs += [_spec((n, h, w, c0)), _spec((n,), jnp.int32)]
    lowered = jax.jit(lambda *a: train_step_flat(*a, cfg=cfg)).lower(*arg_specs)
    artifacts["train_step"] = to_hlo_text(lowered)

    # --- standalone three convolutions at the conv-2 geometry ---------------
    (k2, s2, p2, cin2, cout2) = cfg.convs[1]
    ih2, iw2 = out_hw[0]
    oh2, ow2 = out_hw[1]
    x2 = _spec((n, ih2, iw2, cin2))
    w2 = _spec((k2, k2, cin2, cout2))
    g2 = _spec((n, oh2, ow2, cout2))
    artifacts["conv_fwd"] = to_hlo_text(
        jax.jit(lambda x, w_: conv_fwd(x, w_, stride=s2, padding=p2)).lower(x2, w2)
    )
    artifacts["conv_igrad"] = to_hlo_text(
        jax.jit(
            lambda g, w_: conv_igrad(g, w_, stride=s2, padding=p2,
                                     input_hw=(ih2, iw2))
        ).lower(g2, w2)
    )
    artifacts["conv_wgrad"] = to_hlo_text(
        jax.jit(
            lambda x, g: conv_wgrad(x, g, stride=s2, padding=p2,
                                    kernel_hw=(k2, k2))
        ).lower(x2, g2)
    )

    # --- kernel smoke artifacts ---------------------------------------------
    artifacts["matmul"] = to_hlo_text(
        jax.jit(matmul16).lower(_spec((64, 64)), _spec((64, 64)))
    )
    artifacts["bitmap"] = to_hlo_text(
        jax.jit(zero_bitmap16).lower(_spec((256, 16)))
    )

    for name, text in artifacts.items():
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    # --- meta.json: the rust calling convention ------------------------------
    bitmap_groups_a = [
        (n * hh * ww * cc) // 16
        for (hh, ww), cc in zip(
            [(h, w)] + out_hw[:-1], [c0] + [cv[4] for cv in cfg.convs[:-1]]
        )
    ]
    bitmap_groups_g = [
        (n * hh * ww * cv[4]) // 16 for (hh, ww), cv in zip(out_hw, cfg.convs)
    ]
    meta = {
        "model": {
            "name": cfg.name,
            "batch": n,
            "input": [n, h, w, c0],
            "classes": cfg.classes,
            "lr": cfg.lr,
            "convs": [
                {
                    "kernel": k,
                    "stride": s,
                    "padding": p,
                    "c_in": cin,
                    "c_out": cout,
                    "out_hw": list(ohw),
                }
                for (k, s, p, cin, cout), ohw in zip(cfg.convs, out_hw)
            ],
        },
        "params": [_shape_meta(s) for s in param_shapes],
        "train_step": {
            "args": (
                [_shape_meta(s) for s in param_shapes]
                + [_shape_meta((n, h, w, c0)), _shape_meta((n,), "i32")]
            ),
            "returns": (
                [_shape_meta(s) for s in param_shapes]
                + [_shape_meta(()), _shape_meta(())]
                + [_shape_meta((g,), "i32") for g in bitmap_groups_a]
                + [_shape_meta((g,), "i32") for g in bitmap_groups_g]
            ),
            "bitmap_groups_a": bitmap_groups_a,
            "bitmap_groups_g": bitmap_groups_g,
        },
        "conv2": {
            "x": [n, ih2, iw2, cin2],
            "w": [k2, k2, cin2, cout2],
            "g": [n, oh2, ow2, cout2],
            "stride": s2,
            "padding": p2,
        },
    }
    meta_path = os.path.join(out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {meta_path}")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts",
                        help="artifact output directory")
    args = parser.parse_args()
    export_all(args.out)


if __name__ == "__main__":
    main()
