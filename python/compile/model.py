"""Layer-2 model: a small CNN with the paper's explicit fwd/bwd structure.

The training step is written exactly as the paper decomposes it (§2,
Fig. 5): per layer, ONE forward convolution (Eq. 4), and during
back-propagation ONE input-gradient convolution (Eq. 6) and ONE
weight-gradient convolution (Eq. 8) — each lowered through the Layer-1
Pallas matmul kernel. The backward pass is hand-derived (not ``jax.grad``)
so that the three convolutions exist as distinct computations whose
operand sparsity the rust coordinator can observe; pytest cross-checks
the manual gradients against ``jax.grad`` of a pure-jnp twin.

The train-step artifact additionally returns the per-layer zero bitmaps
(A = input activations, G = output-activation gradients) computed by the
``zero_bitmap16`` Pallas kernel — these drive the cycle-accurate
simulator on the rust side without shipping full tensors.

Channel counts are multiples of 16 to match the PE lane width and the
§3.4 16x16 tensor-group layout.
"""

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .convs import conv_fwd, conv_igrad, conv_wgrad, linear
from .kernels import zero_bitmap16


class ModelConfig(NamedTuple):
    """Static architecture description (shared with rust via meta.json)."""

    batch: int = 16
    height: int = 8
    width: int = 8
    in_channels: int = 16
    classes: int = 10
    lr: float = 0.05
    # (kernel, stride, padding, c_in, c_out) per conv layer.
    convs: tuple = (
        (3, 1, 1, 16, 32),
        (3, 2, 1, 32, 32),
        (3, 1, 1, 32, 32),
    )
    # Model name: written to meta.json so the rust side can label the
    # captured-trace simulation reports with the real model identity.
    name: str = "aot-cnn"

    def conv_out_hw(self):
        h, w = self.height, self.width
        out = []
        for (k, s, p, _, _) in self.convs:
            h = (h + 2 * p - k) // s + 1
            w = (w + 2 * p - k) // s + 1
            out.append((h, w))
        return out

    def flat_dim(self):
        (h, w) = self.conv_out_hw()[-1]
        return h * w * self.convs[-1][4]


CFG = ModelConfig()


def init_params(seed, cfg: ModelConfig = CFG):
    """He-initialised parameters from an int32 seed scalar.

    Exported as its own HLO artifact so the rust coordinator never needs
    python to (re)initialise a model.
    """
    key = jax.random.PRNGKey(seed)
    params = []
    for (k, _, _, cin, cout) in cfg.convs:
        key, sub = jax.random.split(key)
        fan_in = k * k * cin
        params.append(
            jax.random.normal(sub, (k, k, cin, cout), jnp.float32)
            * jnp.sqrt(2.0 / fan_in)
        )
    key, sub = jax.random.split(key)
    params.append(
        jax.random.normal(sub, (cfg.flat_dim(), cfg.classes), jnp.float32)
        * jnp.sqrt(2.0 / cfg.flat_dim())
    )
    params.append(jnp.zeros((cfg.classes,), jnp.float32))
    return tuple(params)


def forward(params, x, cfg: ModelConfig = CFG):
    """Forward pass. Returns logits plus the cache the backward pass needs."""
    convs = params[: len(cfg.convs)]
    wf, bf = params[-2], params[-1]
    acts = [x]  # A^0 .. A^L (post-ReLU inputs of each layer)
    pre = []  # z_l (pre-ReLU), needed for the ReLU mask in bwd
    a = x
    for w, (k, s, p, _, _) in zip(convs, cfg.convs):
        z = conv_fwd(a, w, stride=s, padding=p)
        a = jnp.maximum(z, 0.0)
        pre.append(z)
        acts.append(a)
    flat = a.reshape(a.shape[0], -1)
    logits = linear(flat, wf, bf)
    return logits, (acts, pre, flat)


def _softmax_xent(logits, y, classes):
    lse = jax.scipy.special.logsumexp(logits, axis=1, keepdims=True)
    logp = logits - lse
    onehot = jax.nn.one_hot(y, classes, dtype=jnp.float32)
    loss = -jnp.mean(jnp.sum(onehot * logp, axis=1))
    acc = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
    # dL/dlogits for mean-reduced cross entropy.
    dlogits = (jnp.exp(logp) - onehot) / logits.shape[0]
    return loss, acc, dlogits


def loss_and_grads(params, x, y, cfg: ModelConfig = CFG):
    """Manual forward+backward. Returns (loss, acc, grads, taps).

    ``taps`` carries the tensors whose sparsity the paper exploits: the
    per-layer input activations A_l (ops 1 and 3) and output-activation
    gradients G_l (ops 2 and 3).
    """
    logits, (acts, pre, flat) = forward(params, x, cfg)
    loss, acc, dlogits = _softmax_xent(logits, y, cfg.classes)

    wf = params[-2]
    dwf = jnp.dot(flat.T, dlogits)  # FC weight grad (Eq. 9)
    dbf = jnp.sum(dlogits, axis=0)
    dflat = jnp.dot(dlogits, wf.T)  # FC input grad (Eq. 7)
    da = dflat.reshape(acts[-1].shape)

    conv_ws = params[: len(cfg.convs)]
    dconvs = [None] * len(cfg.convs)
    grads_out = [None] * len(cfg.convs)  # G_l = dL/dz_l, the paper's G_O
    for l in range(len(cfg.convs) - 1, -1, -1):
        (k, s, p, _, _) = cfg.convs[l]
        g = da * (pre[l] > 0.0).astype(jnp.float32)  # ReLU mask -> G_O
        grads_out[l] = g
        # Eq. (8): weight gradients = A_l (*) G_l.
        dconvs[l] = conv_wgrad(acts[l], g, stride=s, padding=p, kernel_hw=(k, k))
        if l > 0:
            # Eq. (6): input gradients = G_l (*) rot180(W_l)^T.
            da = conv_igrad(g, conv_ws[l], stride=s, padding=p,
                            input_hw=acts[l].shape[1:3])
    grads = tuple(dconvs) + (dwf, dbf)
    taps = (acts[: len(cfg.convs)], grads_out)
    return loss, acc, grads, taps


def train_step(params, x, y, cfg: ModelConfig = CFG):
    """One SGD step. Returns (new_params, loss, acc, bitmaps).

    bitmaps = (A-bitmaps per layer ++ G-bitmaps per layer), each an int32
    vector with one 16-lane word per 16-channel group (see kernels/bitmap).
    """
    loss, acc, grads, (acts_in, grads_out) = loss_and_grads(params, x, y, cfg)
    new_params = tuple(p - cfg.lr * g for p, g in zip(params, grads))
    bitmaps = tuple(zero_bitmap16(a) for a in acts_in) + tuple(
        zero_bitmap16(g) for g in grads_out
    )
    return new_params, loss, acc, bitmaps


def train_step_flat(*args, cfg: ModelConfig = CFG):
    """Flat-signature wrapper for AOT export (rust calling convention).

    args = (w1..wL, wf, bf, x, y); returns
    (w1'..wL', wf', bf', loss, acc, ba_0..ba_{L-1}, bg_0..bg_{L-1}).
    """
    n_params = len(cfg.convs) + 2
    params = tuple(args[:n_params])
    x, y = args[n_params], args[n_params + 1]
    new_params, loss, acc, bitmaps = train_step(params, x, y, cfg)
    return tuple(new_params) + (loss, acc) + tuple(bitmaps)


# ---------------------------------------------------------------------------
# Pure-jnp twin (oracle): identical math via lax convolutions + jax.grad.
# Used only by pytest to validate the manual backward pass above.
# ---------------------------------------------------------------------------

def twin_loss(params, x, y, cfg: ModelConfig = CFG):
    from .kernels.ref import conv_fwd_ref

    convs = params[: len(cfg.convs)]
    wf, bf = params[-2], params[-1]
    a = x
    for w, (k, s, p, _, _) in zip(convs, cfg.convs):
        a = jnp.maximum(conv_fwd_ref(a, w, stride=s, padding=p), 0.0)
    logits = jnp.dot(a.reshape(a.shape[0], -1), wf) + bf[None, :]
    lse = jax.scipy.special.logsumexp(logits, axis=1, keepdims=True)
    onehot = jax.nn.one_hot(y, cfg.classes, dtype=jnp.float32)
    return -jnp.mean(jnp.sum(onehot * (logits - lse), axis=1))
