"""L2 model: manual backward pass vs jax.grad, bitmaps, training progress."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels.ref import zero_bitmap_ref
from compile.model import (
    CFG,
    forward,
    init_params,
    loss_and_grads,
    train_step,
    train_step_flat,
    twin_loss,
)


def _batch(seed=0):
    rng = np.random.default_rng(seed)
    x = np.maximum(
        rng.standard_normal((CFG.batch, CFG.height, CFG.width, CFG.in_channels)),
        0.0,
    ).astype(np.float32)
    y = rng.integers(0, CFG.classes, size=(CFG.batch,)).astype(np.int32)
    return x, y


@pytest.fixture(scope="module")
def params():
    return init_params(jnp.int32(42))


def test_param_shapes(params):
    assert len(params) == len(CFG.convs) + 2
    for p, (k, _, _, cin, cout) in zip(params, CFG.convs):
        assert p.shape == (k, k, cin, cout)
    assert params[-2].shape == (CFG.flat_dim(), CFG.classes)
    assert params[-1].shape == (CFG.classes,)


def test_forward_shapes(params):
    x, _ = _batch()
    logits, (acts, pre, flat) = forward(params, x)
    assert logits.shape == (CFG.batch, CFG.classes)
    assert len(acts) == len(CFG.convs) + 1
    for a, (hw, cv) in zip(acts[1:], zip(CFG.conv_out_hw(), CFG.convs)):
        assert a.shape == (CFG.batch, hw[0], hw[1], cv[4])
    assert flat.shape == (CFG.batch, CFG.flat_dim())


def test_manual_grads_match_jax_grad(params):
    """The paper's Eq.(6)/(8) backward vs autodiff of the pure-jnp twin."""
    x, y = _batch(1)
    loss, acc, grads, _ = loss_and_grads(params, x, y)
    twin_l, twin_grads = jax.value_and_grad(twin_loss)(params, x, y)
    assert_allclose(float(loss), float(twin_l), rtol=1e-5)
    assert len(grads) == len(twin_grads)
    for g, tg in zip(grads, twin_grads):
        assert_allclose(np.asarray(g), np.asarray(tg), rtol=1e-3, atol=1e-4)


def test_taps_are_the_papers_tensors(params):
    """acts_in are pre-layer activations; grads_out are dL/dz (post-ReLU-mask)."""
    x, y = _batch(2)
    _, _, _, (acts_in, grads_out) = loss_and_grads(params, x, y)
    assert len(acts_in) == len(CFG.convs)
    assert len(grads_out) == len(CFG.convs)
    # A^0 is the input batch itself.
    assert_allclose(np.asarray(acts_in[0]), x)
    # ReLU-masked gradients must be zero wherever pre-activation <= 0.
    _, (acts, pre, _) = forward(params, x)
    for g, z in zip(grads_out, pre):
        g = np.asarray(g)
        z = np.asarray(z)
        assert np.all(g[z <= 0] == 0.0)


def test_relu_induces_sparsity(params):
    """The premise of the paper: activations/gradients are naturally sparse."""
    x, y = _batch(3)
    _, _, _, (acts_in, grads_out) = loss_and_grads(params, x, y)
    for t in list(acts_in[1:]) + list(grads_out):
        sparsity = float(np.mean(np.asarray(t) == 0.0))
        assert sparsity > 0.2, f"expected natural sparsity, got {sparsity:.3f}"


def test_train_step_bitmaps_match_ref(params):
    x, y = _batch(4)
    _, _, _, bitmaps = train_step(params, x, y)
    _, _, _, (acts_in, grads_out) = loss_and_grads(params, x, y)
    tensors = list(acts_in) + list(grads_out)
    assert len(bitmaps) == len(tensors)
    for bm, t in zip(bitmaps, tensors):
        np.testing.assert_array_equal(
            np.asarray(bm), np.asarray(zero_bitmap_ref(t))
        )


def test_train_step_flat_roundtrip(params):
    x, y = _batch(5)
    outs = train_step_flat(*params, x, y)
    n_params = len(CFG.convs) + 2
    for o, p in zip(outs[:n_params], params):
        assert o.shape == p.shape
    loss, acc = outs[n_params], outs[n_params + 1]
    assert loss.shape == () and acc.shape == ()
    assert 0.0 <= float(acc) <= 1.0


def test_loss_decreases_over_steps(params):
    """A few SGD steps on one batch must reduce the loss (overfit check)."""
    x, y = _batch(6)
    p = params
    first = None
    last = None
    for _ in range(8):
        p, loss, _, _ = train_step(p, x, y)
        first = float(loss) if first is None else first
        last = float(loss)
    assert last < first * 0.9, f"loss did not decrease: {first} -> {last}"
