"""AOT export: every artifact lowers to parseable HLO text + sane meta."""

import json
import os

import pytest

from compile.aot import export_all

ARTIFACTS = [
    "init",
    "train_step",
    "conv_fwd",
    "conv_igrad",
    "conv_wgrad",
    "matmul",
    "bitmap",
]


@pytest.fixture(scope="module")
def outdir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    export_all(str(d))
    return str(d)


@pytest.mark.parametrize("name", ARTIFACTS)
def test_artifact_exists_and_is_hlo_text(outdir, name):
    path = os.path.join(outdir, f"{name}.hlo.txt")
    assert os.path.exists(path)
    text = open(path).read()
    assert "ENTRY" in text, f"{name}: missing ENTRY computation"
    assert "HloModule" in text
    # The interchange gotcha: must be text, never a serialized proto.
    assert not text.startswith("\x08"), "artifact looks like a binary proto"


def test_meta_json(outdir):
    meta = json.load(open(os.path.join(outdir, "meta.json")))
    # The model name labels the rust side's captured-trace reports.
    assert meta["model"]["name"] == "aot-cnn"
    assert meta["model"]["batch"] == 16
    assert len(meta["model"]["convs"]) == 3
    n_params = len(meta["params"])
    assert n_params == 5
    ts = meta["train_step"]
    assert len(ts["args"]) == n_params + 2
    assert len(ts["returns"]) == n_params + 2 + 6
    # bitmap group counts must cover every activation/gradient value once.
    m = meta["model"]
    a_groups = ts["bitmap_groups_a"]
    assert a_groups[0] * 16 == 16 * 8 * 8 * 16


def test_train_step_hlo_has_all_outputs(outdir):
    """The tuple root must carry params + loss + acc + 6 bitmaps = 13 leaves."""
    text = open(os.path.join(outdir, "train_step.hlo.txt")).read()
    root = [l for l in text.splitlines() if "ROOT" in l and "tuple(" in l]
    assert root, "no tuple ROOT in train_step HLO"
