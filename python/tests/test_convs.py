"""The three training convolutions (Eq. 4/6/8) vs lax-based oracles."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.convs import conv_fwd, conv_igrad, conv_wgrad
from compile.kernels.ref import conv_fwd_ref, conv_igrad_ref, conv_wgrad_ref

# (N, H, W, C_in, C_out, K, stride, padding) — includes the model's three
# layer geometries plus stress cases.
GEOMETRIES = [
    (2, 8, 8, 16, 32, 3, 1, 1),  # conv1
    (2, 8, 8, 32, 32, 3, 2, 1),  # conv2 (strided)
    (2, 4, 4, 32, 32, 3, 1, 1),  # conv3
    (1, 6, 6, 16, 16, 1, 1, 0),  # 1x1
    (2, 7, 5, 16, 16, 3, 2, 1),  # odd spatial + stride
    (1, 9, 9, 16, 32, 5, 2, 2),  # 5x5 kernel
]


def _io(n, h, w, cin, cout, k, s, p, seed, sparsity=0.5):
    rng = np.random.default_rng(seed)
    oh = (h + 2 * p - k) // s + 1
    ow = (w + 2 * p - k) // s + 1
    mk = lambda shape: (
        rng.standard_normal(shape) * (rng.random(shape) >= sparsity)
    ).astype(np.float32)
    x = mk((n, h, w, cin))
    wt = mk((k, k, cin, cout))
    g = mk((n, oh, ow, cout))
    return x, wt, g


@pytest.mark.parametrize("geom", GEOMETRIES)
def test_conv_fwd(geom):
    n, h, w, cin, cout, k, s, p = geom
    x, wt, _ = _io(*geom, seed=1)
    assert_allclose(
        conv_fwd(x, wt, stride=s, padding=p),
        conv_fwd_ref(x, wt, stride=s, padding=p),
        rtol=1e-4, atol=1e-4,
    )


@pytest.mark.parametrize("geom", GEOMETRIES)
def test_conv_igrad(geom):
    n, h, w, cin, cout, k, s, p = geom
    x, wt, g = _io(*geom, seed=2)
    assert_allclose(
        conv_igrad(g, wt, stride=s, padding=p, input_hw=(h, w)),
        conv_igrad_ref(g, wt, stride=s, padding=p, input_shape=x.shape),
        rtol=1e-4, atol=1e-4,
    )


@pytest.mark.parametrize("geom", GEOMETRIES)
def test_conv_wgrad(geom):
    n, h, w, cin, cout, k, s, p = geom
    x, wt, g = _io(*geom, seed=3)
    assert_allclose(
        conv_wgrad(x, g, stride=s, padding=p, kernel_hw=(k, k)),
        conv_wgrad_ref(x, g, stride=s, padding=p, kernel_shape=wt.shape),
        rtol=1e-4, atol=1e-4,
    )


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(1, 3),
    hw=st.integers(4, 9),
    cin=st.sampled_from([16, 32]),
    cout=st.sampled_from([16, 32]),
    k=st.sampled_from([1, 3]),
    s=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_all_three_hypothesis(n, hw, cin, cout, k, s, seed):
    p = (k - 1) // 2
    if (hw + 2 * p - k) < 0:
        return
    geom = (n, hw, hw, cin, cout, k, s, p)
    x, wt, g = _io(*geom, seed=seed)
    assert_allclose(
        conv_fwd(x, wt, stride=s, padding=p),
        conv_fwd_ref(x, wt, stride=s, padding=p),
        rtol=1e-4, atol=1e-4,
    )
    assert_allclose(
        conv_igrad(g, wt, stride=s, padding=p, input_hw=(hw, hw)),
        conv_igrad_ref(g, wt, stride=s, padding=p, input_shape=x.shape),
        rtol=1e-4, atol=1e-4,
    )
    assert_allclose(
        conv_wgrad(x, g, stride=s, padding=p, kernel_hw=(k, k)),
        conv_wgrad_ref(x, g, stride=s, padding=p, kernel_shape=wt.shape),
        rtol=1e-4, atol=1e-4,
    )
