"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import matmul16, zero_bitmap16
from compile.kernels.ref import matmul_ref, zero_bitmap_ref


def _rand(shape, rng, sparsity=0.0):
    x = rng.standard_normal(shape).astype(np.float32)
    if sparsity > 0.0:
        mask = rng.random(shape) >= sparsity
        x = x * mask
    return x


@pytest.mark.parametrize(
    "m,k,n",
    [
        (16, 16, 16),  # exactly one PE row
        (32, 16, 32),  # one output tile
        (64, 160, 32),  # multi-step reduction
        (1024, 144, 32),  # conv1 fwd geometry
        (7, 16, 5),  # non-multiples -> padding path
        (33, 17, 31),  # everything misaligned
        (1, 16, 1),  # degenerate
        (128, 512, 10),  # FC geometry
    ],
)
def test_matmul16_matches_ref(m, k, n):
    rng = np.random.default_rng(seed=m * 10007 + k * 101 + n)
    a = _rand((m, k), rng)
    b = _rand((k, n), rng)
    assert_allclose(matmul16(a, b), matmul_ref(a, b), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.9, 1.0])
def test_matmul16_sparse_operands(sparsity):
    """Sparsity must not change numerics (the paper's 'no fidelity loss')."""
    rng = np.random.default_rng(seed=7)
    a = _rand((48, 64), rng, sparsity)
    b = _rand((64, 48), rng, sparsity)
    assert_allclose(matmul16(a, b), matmul_ref(a, b), rtol=1e-5, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 48),
    n=st.integers(1, 40),
    sparsity=st.sampled_from([0.0, 0.7]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul16_hypothesis(m, k, n, sparsity, seed):
    rng = np.random.default_rng(seed)
    a = _rand((m, k), rng, sparsity)
    b = _rand((k, n), rng, sparsity)
    assert_allclose(matmul16(a, b), matmul_ref(a, b), rtol=1e-4, atol=1e-4)


def test_matmul16_rejects_bad_shapes():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        matmul16(_rand((4, 5), rng), _rand((6, 4), rng))
    with pytest.raises(ValueError):
        matmul16(_rand((4,), rng), _rand((4, 4), rng))


@pytest.mark.parametrize("groups", [1, 16, 256, 300])
def test_zero_bitmap_matches_ref(groups):
    rng = np.random.default_rng(seed=groups)
    x = _rand((groups, 16), rng, sparsity=0.6)
    got = np.asarray(zero_bitmap16(x))
    want = np.asarray(zero_bitmap_ref(x))
    assert got.dtype == np.int32
    np.testing.assert_array_equal(got, want)


def test_zero_bitmap_all_zero_and_dense():
    z = np.zeros((8, 16), np.float32)
    np.testing.assert_array_equal(np.asarray(zero_bitmap16(z)), np.zeros(8, np.int32))
    d = np.ones((8, 16), np.float32)
    np.testing.assert_array_equal(
        np.asarray(zero_bitmap16(d)), np.full(8, 0xFFFF, np.int32)
    )


def test_zero_bitmap_bit_positions():
    """Bit l corresponds to lane l (channel-contiguous ordering)."""
    x = np.zeros((2, 16), np.float32)
    x[0, 3] = 1.0
    x[1, 0] = -2.5
    x[1, 15] = 1e-30
    got = np.asarray(zero_bitmap16(x))
    assert got[0] == 1 << 3
    assert got[1] == (1 << 0) | (1 << 15)


def test_zero_bitmap_rejects_unaligned():
    with pytest.raises(ValueError):
        zero_bitmap16(np.zeros((5, 3), np.float32))


@settings(max_examples=10, deadline=None)
@given(groups=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_zero_bitmap_hypothesis(groups, seed):
    rng = np.random.default_rng(seed)
    x = _rand((groups, 16), rng, sparsity=0.5)
    np.testing.assert_array_equal(
        np.asarray(zero_bitmap16(x)), np.asarray(zero_bitmap_ref(x))
    )
